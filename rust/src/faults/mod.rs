//! Seeded, deterministic fault-injection plane.
//!
//! At FastFold's 67-hour × hundreds-of-GPUs scale (and ScaleFold's 2080),
//! rank crashes, comm stalls, and corrupted transfers are the expected
//! case, not the exception. This module is the single source of injected
//! anomalies for the whole stack: a [`FaultSchedule`] of timed events —
//! loaded from JSONL or synthesized from a seed — consumed by the trainer
//! (retry/rollback/elastic dp-shrink), the rank executor (heartbeat
//! detection, `dap/executor.rs`), the DP wire (CRC detect-and-retransmit,
//! `comm/ring.rs`), and the serve daemon (retry/fallback/circuit breaker,
//! `inference/engine/daemon.rs`).
//!
//! Everything here is **virtual-clock deterministic**: events trigger on
//! step numbers and dispatch sequence numbers, never on wall time, so a
//! faulted run replays bit-for-bit and CI can gate recovery outcomes
//! exactly. The plane carries its own recovery-cost bookkeeping
//! ([`RecoveryLedger`]) and the CRC-32 the wire/checkpoint integrity
//! checks share ([`crc32`]).

use crate::error::{Error, Result};
use crate::json::Json;
use crate::rng::Rng;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// One CRC-32 step (IEEE 802.3 reflected polynomial `0xEDB88320`).
fn crc32_byte(crc: u32, byte: u8) -> u32 {
    let mut crc = crc ^ byte as u32;
    for _ in 0..8 {
        let mask = (crc & 1).wrapping_neg();
        crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
    }
    crc
}

/// CRC-32 (IEEE 802.3) of a byte payload — the integrity check the V2
/// checkpoint header and the DP gradient wire share. Bitwise (no table),
/// so the implementation is self-evidently deterministic; the standard
/// check value holds: `crc32(b"123456789") == 0xCBF43926`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = crc32_byte(crc, b);
    }
    !crc
}

/// [`crc32`] over an `f32` payload's little-endian bytes, streamed
/// without materializing the byte buffer — the checksum one DP rank's
/// flattened gradient wire carries (see `comm/ring.rs::payload_crc32`).
pub fn crc32_f32(part: &[f32]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for v in part {
        for b in v.to_le_bytes() {
            crc = crc32_byte(crc, b);
        }
    }
    !crc
}

/// The injectable fault classes (the training-side taxonomy; serving-side
/// backend failures are [`ServeFaultEvent`]s).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Permanent loss of one DP rank: the heartbeat plane marks it dead,
    /// the trainer rolls back to the last valid V2 checkpoint, re-plans
    /// with shrunk `dp` at constant effective batch, and resumes.
    RankCrash,
    /// A collective stalls past the bounded wait: surfaces as a
    /// structured [`crate::Error::CommTimeout`] and is retried.
    CommStall,
    /// One rank's DP wire payload is corrupted in flight: the CRC check
    /// detects the mismatch and the pristine payload is retransmitted.
    CorruptPayload,
    /// One rank runs slow for a step; the run proceeds and the ledger
    /// charges the modeled straggler seconds.
    Straggler,
    /// A transient backend out-of-memory: the step retries with
    /// exponential backoff until the event's budget is exhausted.
    TransientOom,
}

impl FaultKind {
    /// Stable serialized name (`rank_crash`, `comm_stall`, …).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::RankCrash => "rank_crash",
            FaultKind::CommStall => "comm_stall",
            FaultKind::CorruptPayload => "corrupt_payload",
            FaultKind::Straggler => "straggler",
            FaultKind::TransientOom => "transient_oom",
        }
    }

    /// Parse a serialized kind name.
    pub fn parse(s: &str) -> Result<FaultKind> {
        match s {
            "rank_crash" => Ok(FaultKind::RankCrash),
            "comm_stall" => Ok(FaultKind::CommStall),
            "corrupt_payload" => Ok(FaultKind::CorruptPayload),
            "straggler" => Ok(FaultKind::Straggler),
            "transient_oom" => Ok(FaultKind::TransientOom),
            other => Err(Error::Config(format!(
                "faults: unknown kind '{other}' (rank_crash|comm_stall|\
                 corrupt_payload|straggler|transient_oom|backend_fail)"
            ))),
        }
    }

    /// Deterministic sort order inside one step.
    fn order(&self) -> u8 {
        match self {
            FaultKind::TransientOom => 0,
            FaultKind::CommStall => 1,
            FaultKind::CorruptPayload => 2,
            FaultKind::Straggler => 3,
            FaultKind::RankCrash => 4,
        }
    }
}

/// One timed training-side fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// 1-based optimizer step the fault fires at.
    pub step: usize,
    /// What breaks.
    pub kind: FaultKind,
    /// DP rank the fault targets.
    pub rank: usize,
    /// How many injections the event is worth (a `TransientOom` with
    /// `count: 2` fails the first two attempts of the step, then clears).
    pub count: usize,
}

/// One serving-side fault: the daemon's dispatch attempt numbered `at`
/// (0-based, counted across the whole replay) fails `count` consecutive
/// times at the backend before the request succeeds or exhausts retries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeFaultEvent {
    /// 0-based dispatch sequence number the failure run starts at.
    pub at: usize,
    /// Consecutive backend failures injected from `at` on.
    pub count: usize,
}

/// A deterministic schedule of injected faults for one run — training
/// events keyed by optimizer step, serving events keyed by dispatch
/// sequence. Loaded from JSONL ([`FaultSchedule::from_jsonl`]) or
/// synthesized from a seed ([`FaultSchedule::synthesize`]); validated
/// before any run consumes it ([`FaultSchedule::validate`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultSchedule {
    /// Seed the schedule was synthesized from (0 for hand-written files).
    pub seed: u64,
    /// Training-side events, sorted by (step, kind, rank).
    pub train: Vec<FaultEvent>,
    /// Serving-side events, sorted by dispatch sequence.
    pub serve: Vec<ServeFaultEvent>,
}

impl FaultSchedule {
    /// Sort events into the canonical order (stable across load paths).
    fn normalize(&mut self) {
        self.train
            .sort_by_key(|e| (e.step, e.kind.order(), e.rank, e.count));
        self.serve.sort_by_key(|e| (e.at, e.count));
    }

    /// Synthesize a seeded schedule: `transients` transient events
    /// (cycling OOM / stall / straggler / corrupt-payload) over steps
    /// `1..=steps`, one permanent rank crash in the late half of the run
    /// when `dp >= 2` (a crash must leave a shrink target), and
    /// `serve_events` backend-failure runs over an early dispatch window.
    /// Same seed, same schedule — byte-identical JSONL.
    pub fn synthesize(
        seed: u64,
        steps: usize,
        dp: usize,
        transients: usize,
        serve_events: usize,
    ) -> FaultSchedule {
        let mut rng = Rng::new(seed ^ 0x5FA0_17C3_B9E1_D24D);
        let kinds = [
            FaultKind::TransientOom,
            FaultKind::CommStall,
            FaultKind::Straggler,
            FaultKind::CorruptPayload,
        ];
        let mut train = Vec::new();
        for i in 0..transients {
            train.push(FaultEvent {
                step: 1 + rng.below(steps.max(1)),
                kind: kinds[i % kinds.len()],
                rank: rng.below(dp.max(1)),
                count: 1 + rng.below(2),
            });
        }
        if dp >= 2 && steps >= 2 {
            // late-half crash, never step 1: rollback needs at least one
            // checkpointable step before the loss
            let lo = (steps / 2).max(2);
            train.push(FaultEvent {
                step: lo + rng.below(steps - lo + 1),
                kind: FaultKind::RankCrash,
                rank: rng.below(dp),
                count: 1,
            });
        }
        let mut serve = Vec::new();
        let span = (serve_events * 10).max(1);
        for _ in 0..serve_events {
            serve.push(ServeFaultEvent {
                at: rng.below(span),
                count: 1 + rng.below(2),
            });
        }
        let mut s = FaultSchedule { seed, train, serve };
        s.normalize();
        s
    }

    /// Parse a JSONL schedule: one event object per non-blank line.
    /// Training lines carry `kind` + `step` (+ optional `rank`, `count`);
    /// serving lines are `{"kind": "backend_fail", "at": N, "count": K}`.
    /// Unknown keys are loud errors, not silently dropped settings.
    pub fn from_jsonl(src: &str) -> Result<FaultSchedule> {
        let mut out = FaultSchedule::default();
        for (lineno, line) in src.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let j = Json::parse(line)?;
            let obj = j.as_obj()?;
            let kind = j
                .opt("kind")
                .ok_or_else(|| {
                    Error::Config(format!(
                        "faults line {}: missing 'kind'",
                        lineno + 1
                    ))
                })?
                .as_str()?
                .to_string();
            if kind == "backend_fail" {
                for key in obj.keys() {
                    if !["kind", "at", "count"].contains(&key.as_str()) {
                        return Err(Error::Config(format!(
                            "faults line {}: unknown key '{key}' for \
                             backend_fail (known: kind, at, count)",
                            lineno + 1
                        )));
                    }
                }
                let at = j
                    .opt("at")
                    .ok_or_else(|| {
                        Error::Config(format!(
                            "faults line {}: backend_fail needs 'at'",
                            lineno + 1
                        ))
                    })?
                    .as_usize()?;
                let count =
                    match j.opt("count") {
                        Some(v) => v.as_usize()?,
                        None => 1,
                    };
                out.serve.push(ServeFaultEvent { at, count });
            } else {
                for key in obj.keys() {
                    if !["kind", "step", "rank", "count"].contains(&key.as_str())
                    {
                        return Err(Error::Config(format!(
                            "faults line {}: unknown key '{key}' (known: \
                             kind, step, rank, count)",
                            lineno + 1
                        )));
                    }
                }
                let step = j
                    .opt("step")
                    .ok_or_else(|| {
                        Error::Config(format!(
                            "faults line {}: '{kind}' needs 'step'",
                            lineno + 1
                        ))
                    })?
                    .as_usize()?;
                let rank = match j.opt("rank") {
                    Some(v) => v.as_usize()?,
                    None => 0,
                };
                let count = match j.opt("count") {
                    Some(v) => v.as_usize()?,
                    None => 1,
                };
                out.train.push(FaultEvent {
                    step,
                    kind: FaultKind::parse(&kind)?,
                    rank,
                    count,
                });
            }
        }
        out.normalize();
        Ok(out)
    }

    /// Serialize to the JSONL form [`FaultSchedule::from_jsonl`] reads
    /// (round-trips exactly; the seed is not serialized).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for e in &self.train {
            let mut o = BTreeMap::new();
            o.insert("kind".to_string(), Json::Str(e.kind.name().into()));
            o.insert("step".to_string(), Json::Num(e.step as f64));
            o.insert("rank".to_string(), Json::Num(e.rank as f64));
            o.insert("count".to_string(), Json::Num(e.count as f64));
            out.push_str(&Json::Obj(o).to_string());
            out.push('\n');
        }
        for e in &self.serve {
            let mut o = BTreeMap::new();
            o.insert("kind".to_string(), Json::Str("backend_fail".into()));
            o.insert("at".to_string(), Json::Num(e.at as f64));
            o.insert("count".to_string(), Json::Num(e.count as f64));
            out.push_str(&Json::Obj(o).to_string());
            out.push('\n');
        }
        out
    }

    /// Static admission for a training run over `dp` initial DP ranks —
    /// the fault-plane twin of `analysis::admit`: every event must target
    /// a real rank and carry a nonzero budget, steps are 1-based, and
    /// rank crashes must leave at least one surviving rank (each crash
    /// shrinks the fleet, so fewer than `dp` crashes can ever recover).
    pub fn validate(&self, dp: usize) -> Result<()> {
        if dp == 0 {
            return Err(Error::Config("faults: dp must be >= 1".into()));
        }
        let mut crashes = 0usize;
        for e in &self.train {
            if e.step == 0 {
                return Err(Error::Config(format!(
                    "faults: {} event at step 0 (steps are 1-based)",
                    e.kind.name()
                )));
            }
            if e.count == 0 {
                return Err(Error::Config(format!(
                    "faults: {} event at step {} has count 0",
                    e.kind.name(),
                    e.step
                )));
            }
            if e.rank >= dp {
                return Err(Error::Config(format!(
                    "faults: {} event at step {} targets rank {} but the \
                     plan has dp={dp}",
                    e.kind.name(),
                    e.step,
                    e.rank
                )));
            }
            if e.kind == FaultKind::RankCrash {
                crashes += 1;
            }
        }
        if crashes >= dp {
            return Err(Error::Config(format!(
                "faults: {crashes} rank crashes scheduled against dp={dp} — \
                 a crash must leave at least one surviving rank"
            )));
        }
        for e in &self.serve {
            if e.count == 0 {
                return Err(Error::Config(format!(
                    "faults: backend_fail event at dispatch {} has count 0",
                    e.at
                )));
            }
        }
        Ok(())
    }

    /// Scheduled training events firing at 1-based `step`.
    pub fn train_events_at(
        &self,
        step: usize,
    ) -> impl Iterator<Item = &FaultEvent> {
        self.train.iter().filter(move |e| e.step == step)
    }
}

/// Stateful consumer of one schedule's training events: each event has a
/// `count` budget; [`Injector::take`] consumes one injection at a time so
/// a retried step draws the event down and eventually clears it. Held by
/// the trainer (`&mut` methods — the trainer owns all step context).
#[derive(Clone, Debug)]
pub struct Injector {
    schedule: FaultSchedule,
    spent: Vec<usize>,
}

impl Injector {
    /// Wrap a validated schedule with fresh per-event budgets.
    pub fn new(schedule: FaultSchedule) -> Self {
        let spent = vec![0; schedule.train.len()];
        Injector { schedule, spent }
    }

    /// The schedule this injector consumes.
    pub fn schedule(&self) -> &FaultSchedule {
        &self.schedule
    }

    /// Consume one injection of `kind` at 1-based `step`; returns the
    /// target rank, or `None` when no matching event has budget left.
    pub fn take(&mut self, step: usize, kind: FaultKind) -> Option<usize> {
        for (i, e) in self.schedule.train.iter().enumerate() {
            if e.step == step && e.kind == kind && self.spent[i] < e.count {
                self.spent[i] += 1;
                return Some(e.rank);
            }
        }
        None
    }

    /// Remaining injection budget for `kind` at `step`.
    pub fn remaining(&self, step: usize, kind: FaultKind) -> usize {
        self.schedule
            .train
            .iter()
            .enumerate()
            .filter(|(_, e)| e.step == step && e.kind == kind)
            .map(|(i, e)| e.count - self.spent[i])
            .sum()
    }
}

/// Per-rank liveness plane for the rank executor: workers tick their
/// beat as they take work; the fault plane (or a real detector) marks a
/// rank dead, and the next sweep surfaces [`crate::Error::RankLost`]
/// instead of hanging on a rank that will never report. Lock-free —
/// shared across the scoped rank-executor worker threads.
#[derive(Debug)]
pub struct Heartbeats {
    beats: Vec<AtomicU64>,
    dead: Vec<AtomicBool>,
}

impl Heartbeats {
    /// Fresh liveness state for `n` ranks (all alive, zero beats).
    pub fn new(n: usize) -> Self {
        Heartbeats {
            beats: (0..n).map(|_| AtomicU64::new(0)).collect(),
            dead: (0..n).map(|_| AtomicBool::new(false)).collect(),
        }
    }

    /// Ranks this plane tracks.
    pub fn ranks(&self) -> usize {
        self.beats.len()
    }

    /// Record one heartbeat for `rank` (out-of-range ticks are ignored).
    pub fn tick(&self, rank: usize) {
        if let Some(b) = self.beats.get(rank) {
            b.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Beats recorded for `rank` so far.
    pub fn beats(&self, rank: usize) -> u64 {
        self.beats.get(rank).map_or(0, |b| b.load(Ordering::Relaxed))
    }

    /// Declare `rank` permanently lost.
    pub fn mark_dead(&self, rank: usize) {
        if let Some(d) = self.dead.get(rank) {
            d.store(true, Ordering::Relaxed);
        }
    }

    /// Whether `rank` has been declared lost.
    pub fn is_dead(&self, rank: usize) -> bool {
        self.dead.get(rank).is_some_and(|d| d.load(Ordering::Relaxed))
    }

    /// Lowest-numbered dead rank, if any.
    pub fn first_dead(&self) -> Option<usize> {
        (0..self.dead.len()).find(|&r| self.is_dead(r))
    }
}

/// Recovery-cost bookkeeping for one faulted run — the numbers the
/// `TrainReport` ledgers and the MTBF model calibrates against. Seconds
/// are *modeled* (virtual-clock), so the ledger is deterministic.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RecoveryLedger {
    /// Grad-phase attempts retried after a transient fault.
    pub retries: usize,
    /// Wire payloads whose CRC mismatch forced a retransmit.
    pub retransmits: usize,
    /// Bounded collective waits that timed out and retried.
    pub comm_timeouts: usize,
    /// Straggler slowdowns absorbed without a retry.
    pub stragglers: usize,
    /// Permanent rank losses recovered by rollback + dp-shrink.
    pub rank_crashes: usize,
    /// Optimizer steps re-run because of rollback to a checkpoint.
    pub lost_steps: usize,
    /// Modeled seconds spent in backoff, retransmits, and rollback.
    pub recovery_seconds: f64,
}

impl RecoveryLedger {
    /// The cost accumulated since `earlier` was captured — what one
    /// `run_schedule` call reports when the trainer's cumulative ledger
    /// already carries a previous run's counts.
    #[must_use]
    pub fn since(&self, earlier: &RecoveryLedger) -> RecoveryLedger {
        RecoveryLedger {
            retries: self.retries - earlier.retries,
            retransmits: self.retransmits - earlier.retransmits,
            comm_timeouts: self.comm_timeouts - earlier.comm_timeouts,
            stragglers: self.stragglers - earlier.stragglers,
            rank_crashes: self.rank_crashes - earlier.rank_crashes,
            lost_steps: self.lost_steps - earlier.lost_steps,
            recovery_seconds: self.recovery_seconds - earlier.recovery_seconds,
        }
    }

    /// Whether any fault was absorbed at all.
    pub fn any(&self) -> bool {
        self.retries
            + self.retransmits
            + self.comm_timeouts
            + self.stragglers
            + self.rank_crashes
            + self.lost_steps
            > 0
    }
}

/// Modeled exponential backoff before retry `attempt` (1-based):
/// `base * 2^(attempt-1)`, capped at 16 doublings.
pub fn backoff_secs(base: f64, attempt: usize) -> f64 {
    base * f64::from(1u32 << (attempt.clamp(1, 17) - 1).min(16))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_ieee_check_value() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // f32 streaming form agrees with the byte form
        let part = [1.0f32, -2.5, 3.25e7];
        let bytes: Vec<u8> =
            part.iter().flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(crc32_f32(&part), crc32(&bytes));
        // and detects a single-bit flip
        let mut flipped = part;
        flipped[1] = f32::from_bits(flipped[1].to_bits() ^ 1);
        assert_ne!(crc32_f32(&flipped), crc32_f32(&part));
    }

    #[test]
    fn jsonl_roundtrip_is_exact() {
        let src = r#"
            {"kind": "transient_oom", "step": 2, "rank": 0, "count": 2}
            # comment
            {"kind": "comm_stall", "step": 3}
            {"kind": "rank_crash", "step": 5, "rank": 1}
            {"kind": "backend_fail", "at": 7, "count": 2}
        "#;
        let s = FaultSchedule::from_jsonl(src).unwrap();
        assert_eq!(s.train.len(), 3);
        assert_eq!(s.serve.len(), 1);
        assert_eq!(s.train[1].kind, FaultKind::CommStall);
        assert_eq!((s.train[1].rank, s.train[1].count), (0, 1));
        let back = FaultSchedule::from_jsonl(&s.to_jsonl()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn jsonl_rejects_unknown_keys_and_kinds() {
        assert!(FaultSchedule::from_jsonl(r#"{"kind": "gremlin", "step": 1}"#)
            .is_err());
        assert!(FaultSchedule::from_jsonl(
            r#"{"kind": "comm_stall", "step": 1, "lane": 3}"#
        )
        .is_err());
        assert!(FaultSchedule::from_jsonl(
            r#"{"kind": "backend_fail", "step": 1}"#
        )
        .is_err());
        assert!(FaultSchedule::from_jsonl(r#"{"step": 1}"#).is_err());
    }

    #[test]
    fn validate_enforces_ranks_and_survivors() {
        let mut s = FaultSchedule::default();
        s.train.push(FaultEvent {
            step: 1,
            kind: FaultKind::CommStall,
            rank: 2,
            count: 1,
        });
        assert!(s.validate(2).is_err()); // rank out of range
        assert!(s.validate(4).is_ok());
        let crash = |rank| FaultEvent {
            step: 3,
            kind: FaultKind::RankCrash,
            rank,
            count: 1,
        };
        let one = FaultSchedule {
            seed: 0,
            train: vec![crash(0)],
            serve: vec![],
        };
        assert!(one.validate(1).is_err()); // no survivor
        assert!(one.validate(2).is_ok());
        let zero_count = FaultSchedule {
            seed: 0,
            train: vec![FaultEvent {
                step: 1,
                kind: FaultKind::Straggler,
                rank: 0,
                count: 0,
            }],
            serve: vec![],
        };
        assert!(zero_count.validate(2).is_err());
    }

    #[test]
    fn synthesize_is_deterministic_sorted_and_admissible() {
        let a = FaultSchedule::synthesize(11, 8, 4, 3, 2);
        let b = FaultSchedule::synthesize(11, 8, 4, 3, 2);
        assert_eq!(a, b);
        assert_ne!(a, FaultSchedule::synthesize(12, 8, 4, 3, 2));
        a.validate(4).unwrap();
        assert!(a.train.windows(2).all(|w| w[0].step <= w[1].step));
        assert_eq!(
            a.train
                .iter()
                .filter(|e| e.kind == FaultKind::RankCrash)
                .count(),
            1
        );
        assert!(a.train.iter().any(|e| e.kind != FaultKind::RankCrash));
        assert_eq!(a.serve.len(), 2);
        // dp=1 schedules no crash (nothing to shrink to)
        assert!(FaultSchedule::synthesize(11, 8, 1, 2, 0)
            .train
            .iter()
            .all(|e| e.kind != FaultKind::RankCrash));
    }

    #[test]
    fn injector_draws_event_budgets_down() {
        let s = FaultSchedule::from_jsonl(
            r#"{"kind": "transient_oom", "step": 2, "rank": 1, "count": 2}"#,
        )
        .unwrap();
        let mut inj = Injector::new(s);
        assert_eq!(inj.remaining(2, FaultKind::TransientOom), 2);
        assert_eq!(inj.take(1, FaultKind::TransientOom), None);
        assert_eq!(inj.take(2, FaultKind::CommStall), None);
        assert_eq!(inj.take(2, FaultKind::TransientOom), Some(1));
        assert_eq!(inj.take(2, FaultKind::TransientOom), Some(1));
        assert_eq!(inj.take(2, FaultKind::TransientOom), None);
        assert_eq!(inj.remaining(2, FaultKind::TransientOom), 0);
    }

    #[test]
    fn heartbeats_track_ticks_and_death() {
        let hb = Heartbeats::new(3);
        assert_eq!(hb.ranks(), 3);
        hb.tick(0);
        hb.tick(0);
        hb.tick(2);
        hb.tick(9); // out of range: ignored
        assert_eq!((hb.beats(0), hb.beats(1), hb.beats(2)), (2, 0, 1));
        assert_eq!(hb.first_dead(), None);
        hb.mark_dead(1);
        assert!(hb.is_dead(1));
        assert!(!hb.is_dead(0));
        assert_eq!(hb.first_dead(), Some(1));
    }

    #[test]
    fn backoff_doubles_and_saturates() {
        assert_eq!(backoff_secs(0.05, 1), 0.05);
        assert_eq!(backoff_secs(0.05, 2), 0.1);
        assert_eq!(backoff_secs(0.05, 3), 0.2);
        // attempt 0 is treated as the first attempt; huge attempts cap
        assert_eq!(backoff_secs(0.05, 0), 0.05);
        assert!(backoff_secs(0.05, 1000).is_finite());
    }
}
