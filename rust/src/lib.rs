//! # FastFold (reproduction)
//!
//! A three-layer reproduction of *FastFold: Reducing AlphaFold Training
//! Time from 11 Days to 67 Hours* (Cheng et al., 2022):
//!
//! * **L1** — Pallas kernels (fused softmax / Welford LayerNorm / gated
//!   attention / triangle update / outer-product-mean), AOT-lowered to HLO
//!   text by the python compile path (`python/compile/`).
//! * **L2** — the JAX Evoformer / mini-AlphaFold model and its Dynamic
//!   Axial Parallelism segment decomposition, also AOT-lowered.
//! * **L3** — this crate: the coordinator. Loads the HLO artifacts through
//!   PJRT ([`runtime`]), shards activations across logical ranks, executes
//!   the DAP schedule on a threaded rank executor with real (wall-clock)
//!   Duality-Async overlap via a dedicated comm worker thread ([`dap`],
//!   [`comm::worker`]; `--threads 1` restores the bit-identical
//!   sequential path), runs the
//!   Megatron-style TP baseline ([`tp`]), hybrid DP×DAP training with
//!   gradient accumulation, a two-stage recipe, and resumable full-state
//!   checkpoints ([`train`]), chunked + distributed inference ([`inference`]) with the
//!   AutoChunk planner ([`inference::autochunk`]) choosing per-module
//!   chunk strategies against the memory cost model, the unified serving
//!   engine ([`inference::engine`]) placing and scheduling whole request
//!   batches across the single-device/chunked/DAP backends, and the
//!   calibrated A100 performance/memory models that regenerate the
//!   paper's scaling figures ([`perfmodel`]). The host data plane is
//!   zero-copy ([`tensor`]: Arc-backed views with copy-on-write), the
//!   paper's fused kernels run natively on host next to their naive op
//!   chains ([`kernels`]) and dispatch through the pluggable
//!   [`device`] backends (scalar oracle / f32x8 lanes with within-op
//!   threading / xla stub), and `fastfold bench` ([`bench`]) emits the
//!   `BENCH_host.json` perf ledger.
//!
//! Python never runs on the request path: `make artifacts` exports
//! everything once, then the `fastfold` binary is self-contained. This
//! offline build links the stub `xla` crate (`rust/xla`): literals and
//! every pure-model path are fully functional; artifact *execution* is
//! gated behind a descriptive error until real PJRT bindings are linked.

#![warn(missing_docs)]

pub mod analysis;
pub mod bench;
pub mod comm;
pub mod config;
pub mod dap;
pub mod device;
pub mod error;
pub mod faults;
pub mod inference;
pub mod json;
pub mod kernels;
pub mod manifest;
pub mod metrics;
pub mod perfmodel;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod tp;
pub mod train;

pub use error::{Error, Result};
pub use tensor::{HostTensor, IntTensor};
