//! Device calibration constants for the analytic models.
//!
//! A100 numbers follow the public datasheet; the *achieved-efficiency*
//! factors are where the paper's kernel work lands: the baseline
//! (OpenFold/PyTorch) spends 55.7% of time in Batch Reduction and only
//! 14.7% in GEMM (paper §III.B), so its effective throughput is far below
//! peak; FastFold's fused kernels pull the non-GEMM time down by the
//! Fig 8/9 factors. We encode both as effective-FLOPS multipliers and
//! *calibrate the shape, not absolute numbers* — EXPERIMENTS.md compares
//! ratios against the paper's.

#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    /// peak dense bf16 FLOPs/s
    pub peak_flops: f64,
    /// HBM bandwidth bytes/s
    pub hbm_bw: f64,
    /// memory capacity bytes
    pub memory: f64,
}

impl GpuSpec {
    pub fn a100_40g() -> Self {
        GpuSpec {
            name: "A100-40G",
            peak_flops: 312e12,
            hbm_bw: 1.55e12,
            memory: 40e9,
        }
    }

    pub fn tpu_v3() -> Self {
        GpuSpec {
            name: "TPUv3",
            peak_flops: 123e12,
            hbm_bw: 0.9e12,
            memory: 16e9,
        }
    }

    /// H100 SXM (80 GB HBM3): the ScaleFold platform (arXiv:2404.11068).
    /// Datasheet: 989 TFLOP/s dense bf16, 3.35 TB/s HBM3.
    pub fn h100_80g() -> Self {
        GpuSpec {
            name: "H100-80G",
            peak_flops: 989e12,
            hbm_bw: 3.35e12,
            memory: 80e9,
        }
    }

    /// Look up a device by config/CLI name (`a100_40g`, `tpu_v3`,
    /// `h100_80g`). Also accepts the display names (`A100-40G`, `TPUv3`,
    /// `H100-80G`) so a serialized `AutoChunkPlan`'s `gpu` field resolves
    /// back to its spec.
    pub fn by_name(name: &str) -> crate::error::Result<Self> {
        match name {
            "a100_40g" | "a100" | "A100-40G" => Ok(Self::a100_40g()),
            "tpu_v3" | "tpuv3" | "TPUv3" => Ok(Self::tpu_v3()),
            "h100_80g" | "h100" | "H100-80G" => Ok(Self::h100_80g()),
            other => Err(crate::error::Error::Config(format!(
                "unknown gpu spec '{other}' (known: a100_40g, tpu_v3, h100_80g)"
            ))),
        }
    }
}

/// Achieved-efficiency model for one implementation of the Evoformer.
///
/// Runtime = GEMM time (peak × mxu_eff) + batch-reduce time (HBM-bound,
/// bytes/bw × reduce_passes) + elementwise time (HBM-bound). The
/// implementation's kernel quality enters through `reduce_passes` (how many
/// HBM round-trips per element the softmax/LN chains make) and `mxu_eff`.
#[derive(Clone, Copy, Debug)]
pub struct ImplProfile {
    pub name: &'static str,
    pub mxu_eff: f64,
    /// HBM passes per batch-reduce element (unfused chains re-read)
    pub reduce_passes: f64,
    /// HBM passes per elementwise element
    pub elem_passes: f64,
}

impl ImplProfile {
    /// PyTorch-native kernels (OpenFold baseline): the paper measures the
    /// softmax chain at 8 HBM passes (scale, bias, mask, max, sub, exp,
    /// sum, div) and LN two-pass at ~6.
    pub fn openfold() -> Self {
        ImplProfile { name: "OpenFold", mxu_eff: 0.45, reduce_passes: 4.5, elem_passes: 2.0 }
    }

    /// FastFold fused kernels: one read + one write per element.
    pub fn fastfold() -> Self {
        ImplProfile { name: "FastFold", mxu_eff: 0.50, reduce_passes: 2.0, elem_passes: 1.0 }
    }

    /// AlphaFold-JAX on GPU (paper §V.C: JAX GPU kernels are weaker, plus
    /// XLA's generic fusions): between the two, closer to OpenFold.
    pub fn alphafold_jax_gpu() -> Self {
        ImplProfile { name: "AlphaFold-JAX", mxu_eff: 0.38, reduce_passes: 5.5, elem_passes: 2.0 }
    }

    /// AlphaFold on TPUv3 (the original training platform).
    pub fn alphafold_tpu() -> Self {
        ImplProfile { name: "AlphaFold-TPU", mxu_eff: 0.50, reduce_passes: 3.5, elem_passes: 1.5 }
    }

    /// ScaleFold (arXiv:2404.11068): FastFold-class fusion plus CUDA-graph
    /// launch elimination, non-blocking data pipeline, and bf16 compute —
    /// higher achieved MXU occupancy and fewer HBM round-trips still.
    pub fn scalefold() -> Self {
        ImplProfile { name: "ScaleFold", mxu_eff: 0.60, reduce_passes: 1.5, elem_passes: 1.0 }
    }

    /// Profile for a host device-backend selection (`[device] backend`).
    /// `"simd"` and `"xla-stub"` price as the fused [`Self::fastfold`]
    /// profile (the stub lowers through the same fused plan); the scalar
    /// oracle trades lanes for auditability — fewer elements per cycle
    /// shows up as extra effective passes and lower MXU efficiency.
    /// Unknown names price conservatively (scalar-like) rather than
    /// erroring: the config layer already rejects typos eagerly.
    pub fn for_device_backend(backend: &str) -> Self {
        match backend {
            "simd" | "xla-stub" => Self::fastfold(),
            "scalar" => ImplProfile {
                name: "ScalarHost",
                mxu_eff: 0.50,
                reduce_passes: 4.0,
                elem_passes: 2.0,
            },
            _ => ImplProfile {
                name: "UnknownHost",
                mxu_eff: 0.50,
                reduce_passes: 4.0,
                elem_passes: 2.0,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fastfold_fewer_passes() {
        assert!(ImplProfile::fastfold().reduce_passes < ImplProfile::openfold().reduce_passes);
        assert!(ImplProfile::fastfold().elem_passes <= ImplProfile::openfold().elem_passes);
    }

    #[test]
    fn device_backend_profiles() {
        // the default "simd" selection must keep the modeled ledgers
        // byte-identical to the historical fastfold profile
        assert_eq!(ImplProfile::for_device_backend("simd").name, "FastFold");
        assert_eq!(ImplProfile::for_device_backend("xla-stub").name, "FastFold");
        let scalar = ImplProfile::for_device_backend("scalar");
        assert_eq!(scalar.name, "ScalarHost");
        assert!(scalar.reduce_passes > ImplProfile::fastfold().reduce_passes);
        // unknown names price conservatively, not panic
        assert!(
            ImplProfile::for_device_backend("mystery").reduce_passes
                >= scalar.reduce_passes
        );
    }

    #[test]
    fn a100_datasheet() {
        let g = GpuSpec::a100_40g();
        assert_eq!(g.peak_flops, 312e12);
        assert_eq!(g.memory, 40e9);
    }

    #[test]
    fn h100_datasheet_and_lookup() {
        let g = GpuSpec::h100_80g();
        assert_eq!(g.peak_flops, 989e12);
        assert_eq!(g.memory, 80e9);
        assert!(g.hbm_bw > GpuSpec::a100_40g().hbm_bw);
        assert_eq!(GpuSpec::by_name("h100").unwrap().name, "H100-80G");
        assert_eq!(GpuSpec::by_name("H100-80G").unwrap().name, "H100-80G");
    }

    #[test]
    fn scalefold_profile_beats_fastfold() {
        let sf = ImplProfile::scalefold();
        let ff = ImplProfile::fastfold();
        assert!(sf.mxu_eff > ff.mxu_eff);
        assert!(sf.reduce_passes < ff.reduce_passes);
    }
}
