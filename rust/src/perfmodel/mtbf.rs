//! MTBF-driven wall-clock inflation model for faulted training runs.
//!
//! FastFold's headline 67-hour run assumes a perfect fleet; at hundreds
//! of GPUs (ScaleFold: 2080) failures arrive at a measurable rate and
//! the real wall-clock inflates by (a) work lost since the last
//! checkpoint on each failure, (b) rollback/restart latency, and (c) the
//! steady-state checkpointing tax. This module projects that inflation
//! analytically: a fleet with per-run mean-time-between-failures `M`
//! hours suffers `T/M` expected failures over a `T`-hour run, each
//! costing half a checkpoint interval of lost work plus the recovery
//! time, while every interval pays the checkpoint write. The optimal
//! interval is Young's approximation `τ* = sqrt(2·M·C)`.
//!
//! The projection anchors on [`crate::perfmodel::ScalingModel`]'s
//! fault-free two-stage hours, so `fastfold chaos` can print the
//! expected 67-hour inflation as a function of fleet failure rate, and
//! the trainer's measured [`crate::faults::RecoveryLedger`] gives the
//! empirical counterpart at synthetic scale.

/// Analytic model of expected wall-clock under a failure rate.
#[derive(Clone, Copy, Debug)]
pub struct MtbfModel {
    /// Fleet-level mean time between failures, hours (whole-job MTBF:
    /// per-device MTBF divided by device count).
    pub mtbf_hours: f64,
    /// Checkpoint interval, hours.
    pub interval_hours: f64,
    /// Wall-clock cost of writing one checkpoint, hours.
    pub write_hours: f64,
    /// Rollback + re-plan + restart latency per failure, hours.
    pub restart_hours: f64,
}

impl Default for MtbfModel {
    /// A 512-GPU-class fleet: whole-job MTBF of 24 h, 10-minute
    /// checkpoint cadence, 30 s writes, 5-minute restart.
    fn default() -> Self {
        MtbfModel {
            mtbf_hours: 24.0,
            interval_hours: 10.0 / 60.0,
            write_hours: 30.0 / 3600.0,
            restart_hours: 5.0 / 60.0,
        }
    }
}

impl MtbfModel {
    /// Young's optimal checkpoint interval `sqrt(2·M·C)` in hours — the
    /// interval that balances checkpoint tax against expected rework.
    pub fn optimal_interval_hours(&self) -> f64 {
        (2.0 * self.mtbf_hours * self.write_hours).max(0.0).sqrt()
    }

    /// Fraction of wall-clock lost to faults and checkpointing: the
    /// per-failure loss rate `(τ/2 + R) / M` plus the checkpoint tax
    /// `C / τ`. Values ≥ 1 mean the run makes no forward progress.
    pub fn overhead_fraction(&self) -> f64 {
        let tau = self.interval_hours.max(1e-9);
        (tau / 2.0 + self.restart_hours) / self.mtbf_hours.max(1e-9)
            + self.write_hours / tau
    }

    /// Expected wall-clock hours for a run whose fault-free compute time
    /// is `base_hours`: `T / (1 − overhead)`. Returns `f64::INFINITY`
    /// when the overhead fraction reaches 1 (the fleet fails faster than
    /// it can recover).
    pub fn expected_wall_hours(&self, base_hours: f64) -> f64 {
        let avail = 1.0 - self.overhead_fraction();
        if avail <= 0.0 {
            f64::INFINITY
        } else {
            base_hours / avail
        }
    }

    /// Multiplicative inflation over the fault-free run
    /// (`expected / base`, so 1.0 = no inflation).
    pub fn inflation(&self, base_hours: f64) -> f64 {
        self.expected_wall_hours(base_hours) / base_hours.max(1e-9)
    }

    /// The same model re-tuned to Young's optimal interval.
    pub fn with_optimal_interval(mut self) -> Self {
        self.interval_hours = self.optimal_interval_hours().max(1e-9);
        self
    }
}

/// Project expected wall-clock for the paper's run across a sweep of
/// fleet MTBF values (hours). Returns `(mtbf_hours, expected_hours,
/// inflation)` rows, using Young's optimal interval at each point — the
/// table `fastfold chaos` prints against the 67-hour baseline.
pub fn inflation_sweep(
    base_hours: f64,
    mtbf_sweep: &[f64],
) -> Vec<(f64, f64, f64)> {
    mtbf_sweep
        .iter()
        .map(|&m| {
            let model = MtbfModel { mtbf_hours: m, ..MtbfModel::default() }
                .with_optimal_interval();
            let wall = model.expected_wall_hours(base_hours);
            (m, wall, model.inflation(base_hours))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::ScalingModel;

    #[test]
    fn healthy_fleet_inflates_mildly() {
        let m = MtbfModel::default();
        let base = 67.0;
        let wall = m.expected_wall_hours(base);
        assert!(wall > base, "faults must cost something: {wall}");
        assert!(wall < base * 1.25, "24h-MTBF overhead is small: {wall}");
    }

    #[test]
    fn inflation_decreases_with_mtbf() {
        let rows = inflation_sweep(67.0, &[2.0, 8.0, 24.0, 168.0]);
        assert_eq!(rows.len(), 4);
        for w in rows.windows(2) {
            assert!(
                w[0].2 > w[1].2,
                "inflation must fall as MTBF rises: {rows:?}"
            );
        }
        for (_, wall, infl) in &rows {
            assert!(*wall > 67.0 && *infl > 1.0);
        }
    }

    #[test]
    fn dying_fleet_never_finishes() {
        let m = MtbfModel {
            mtbf_hours: 0.01,
            interval_hours: 0.5,
            restart_hours: 0.2,
            ..MtbfModel::default()
        };
        assert!(m.expected_wall_hours(67.0).is_infinite());
    }

    #[test]
    fn youngs_interval_beats_fixed_intervals() {
        let base = MtbfModel { mtbf_hours: 6.0, ..MtbfModel::default() };
        let tuned = base.with_optimal_interval();
        let opt = tuned.overhead_fraction();
        for tau in [0.01, 0.05, 0.5, 1.0, 2.0] {
            let fixed = MtbfModel { interval_hours: tau, ..base };
            assert!(
                opt <= fixed.overhead_fraction() + 1e-12,
                "tau* must minimize overhead (tau={tau})"
            );
        }
        // Young: tau* = sqrt(2 M C)
        let expect = (2.0 * 6.0 * base.write_hours).sqrt();
        assert!((tuned.interval_hours - expect).abs() < 1e-12);
    }

    #[test]
    fn projects_the_67_hour_run() {
        // anchor on the calibrated two-stage total (pinned elsewhere to
        // the paper's 55–80h band), then project a weekly-failure fleet
        let p = crate::perfmodel::gpu::ImplProfile::fastfold();
        let sm = ScalingModel::default();
        let (init, ft) = sm.two_stage_hours(&p, (2, 128), (4, 128));
        let base = init + ft;
        let model = MtbfModel { mtbf_hours: 168.0, ..MtbfModel::default() }
            .with_optimal_interval();
        let wall = model.expected_wall_hours(base);
        assert!(wall > base && wall < base * 1.05, "weekly MTBF: {wall}");
    }
}
