//! Calibrated analytic performance + memory models.
//!
//! The paper's scaling results (Figs 10–13, Tables IV–V) were measured on
//! 128 nodes × 4 A100; this testbed is one CPU core, so absolute wall-clock
//! cannot transfer. What does transfer is *structure*: FLOP counts per
//! module ([`flops`]), activation footprints ([`memory`]), collective
//! volumes (measured by the comm log), and the α–β link models. [`scaling`]
//! combines them into step-time predictions whose *shape* (who wins, by
//! what factor, where OOM hits, where efficiency falls off) reproduces the
//! paper's evaluation. Calibration constants live in [`gpu`].

pub mod flops;
pub mod gpu;
pub mod memory;
pub mod mtbf;
pub mod scaling;

pub use flops::BlockFlops;
pub use gpu::GpuSpec;
pub use memory::MemoryModel;
pub use mtbf::MtbfModel;
pub use scaling::{DpOverlap, DpStepModel, ScalingModel, StepTime};
