//! Step-time scaling model: combines FLOP counts, implementation profiles,
//! link models, and measured collective volumes into the paper's scaling
//! curves (Figs 10–13, Tables IV–V). Shapes, not absolute numbers — see
//! DESIGN.md §2 and EXPERIMENTS.md for paper-vs-model comparisons.

use super::flops::{block_flops, BlockFlops};
use super::gpu::{GpuSpec, ImplProfile};
use crate::config::ModelConfig;
use crate::dap::CommCost;

/// Mean recycling passes during training (uniform 1..4 → extra forwards)
/// and fixed 4 at inference (paper §II.A).
pub const TRAIN_RECYCLES: f64 = 2.5;
pub const INFER_RECYCLES: f64 = 4.0;

#[derive(Clone, Copy, Debug, Default)]
pub struct StepTime {
    pub compute: f64,
    pub comm: f64,
    /// comm left exposed after computation–communication overlap
    pub exposed_comm: f64,
}

impl StepTime {
    pub fn total(&self) -> f64 {
        self.compute + self.exposed_comm
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MpMethod {
    Dap,
    TensorParallel,
}

#[derive(Clone, Copy, Debug)]
pub struct ScalingModel {
    pub gpu: GpuSpec,
    pub intra: CommCost,
    pub inter: CommCost,
    /// Whole-pipeline structural multiplier: this model prices the
    /// Evoformer trunk (48 blocks at the Table I cluster sizes); the real
    /// AlphaFold step also runs the extra-MSA stack (~5120 sequences),
    /// template stack, structure module and input pipeline. Calibrated
    /// ONCE against OpenFold's published initial-training step (6.186 s,
    /// paper Table IV) and applied uniformly — it cancels out of every
    /// ratio (speedups, efficiencies) and only anchors absolute seconds.
    pub pipeline_mult: f64,
}

impl Default for ScalingModel {
    fn default() -> Self {
        ScalingModel {
            gpu: GpuSpec::a100_40g(),
            intra: CommCost::nvlink(),
            inter: CommCost::infiniband(),
            pipeline_mult: 6.2,
        }
    }
}

impl ScalingModel {
    /// Compute time of one block forward on one device given the module
    /// FLOPs it actually executes.
    fn block_compute(&self, f: &BlockFlops, p: &ImplProfile, elem_bytes: f64) -> f64 {
        let t_gemm = (f.gemm + f.attention + f.triangle + f.opm)
            / (self.gpu.peak_flops * p.mxu_eff);
        let t_reduce = f.batch_reduce_elems * elem_bytes * p.reduce_passes / self.gpu.hbm_bw;
        let t_elem = f.elementwise_elems * elem_bytes * p.elem_passes / self.gpu.hbm_bw;
        t_gemm + t_reduce + t_elem
    }

    /// DAP per-block forward comm volume per rank (mirrors the manifest
    /// schedule: 5 gathers, 1 reduce-scatter, 4 all-to-alls).
    pub fn dap_comm_bytes(&self, cfg: &ModelConfig, n: usize, elem_bytes: f64) -> Vec<(f64, bool)> {
        if n <= 1 {
            return vec![];
        }
        let s = cfg.n_seq as f64;
        let r = cfg.n_res as f64;
        let nf = n as f64;
        let frac = (nf - 1.0) / nf;
        // (bytes, overlappable?) per collective
        let mut v = Vec::new();
        let gather = |full_elems: f64| full_elems * elem_bytes * frac;
        // bias gathers (row, tri-start, tri-end): full (r,r,h)
        v.push((gather(r * r * cfg.n_heads_msa as f64), true));
        v.push((gather(r * r * cfg.n_heads_pair as f64), true));
        v.push((gather(r * r * cfg.n_heads_pair as f64), true));
        // OPM right-projection gather: (s, r, d_opm)
        v.push((gather(s * r * cfg.d_opm as f64), true));
        // triangle-out b gather: (r, r, dz)
        v.push((gather(r * r * cfg.d_pair as f64), false));
        // triangle-in reduce-scatter: (r, r, dz) partial
        v.push((r * r * cfg.d_pair as f64 * elem_bytes * frac, false));
        // 4 × all_to_all: local tensor × (n-1)/n — m twice, z twice
        let m_local = s * r * cfg.d_msa as f64 / nf;
        let z_local = r * r * cfg.d_pair as f64 / nf;
        v.push((m_local * elem_bytes * frac, false));
        v.push((m_local * elem_bytes * frac, true)); // a2a_m overlaps pair stack
        v.push((z_local * elem_bytes * frac, false));
        v.push((z_local * elem_bytes * frac, false));
        v
    }

    /// TP per-block forward comm: 6 AllReduce of full intermediates
    /// (paper Table III), ring volume 2(n−1)/n each. None overlappable.
    pub fn tp_comm_bytes(&self, cfg: &ModelConfig, n: usize, elem_bytes: f64) -> Vec<(f64, bool)> {
        if n <= 1 {
            return vec![];
        }
        let s = cfg.n_seq as f64;
        let r = cfg.n_res as f64;
        let ring = 2.0 * (n as f64 - 1.0) / n as f64;
        let msa = s * r * cfg.d_msa as f64 * elem_bytes * ring;
        let pair = r * r * cfg.d_pair as f64 * elem_bytes * ring;
        vec![
            (msa, false), // row attn out
            (msa, false), // col attn out
            (msa, false), // msa transition
            (pair, false), // tri start attn
            (pair, false), // tri end attn
            (pair, false), // pair transition
        ]
    }

    /// Model-parallel step time per block-forward at degree `n`.
    /// `training` doubles comm (bwd collectives) and triples compute
    /// (fwd+bwd); Duality-Async overlap hides overlappable collectives
    /// behind compute when `overlap`.
    pub fn mp_block_time(
        &self,
        cfg: &ModelConfig,
        p: &ImplProfile,
        method: MpMethod,
        n: usize,
        training: bool,
        overlap: bool,
    ) -> StepTime {
        let elem = 2.0; // bf16
        let f = block_flops(cfg, cfg.n_seq, cfg.n_res);
        let nf = n as f64;
        let compute_1 = self.block_compute(&f, p, elem);
        let (compute, comms) = match method {
            MpMethod::Dap => {
                // every module parallelizes: 1/n compute per rank
                (compute_1 / nf, self.dap_comm_bytes(cfg, n, elem))
            }
            MpMethod::TensorParallel => {
                // only attention+FF parallelize; triangle-mult + OPM are
                // replicated (paper §IV.B.1); TP degree capped at pair heads
                let n_eff = n.min(cfg.n_heads_pair);
                let nf_eff = n_eff as f64;
                let repl = BlockFlops { triangle: f.triangle, opm: f.opm, ..Default::default() };
                let par = BlockFlops {
                    gemm: f.gemm,
                    attention: f.attention,
                    // batch-reduce & elementwise follow their tensors
                    batch_reduce_elems: f.batch_reduce_elems,
                    elementwise_elems: f.elementwise_elems,
                    ..Default::default()
                };
                let t = self.block_compute(&par, p, elem) / nf_eff
                    + self.block_compute(&repl, p, elem)
                    // replicated triangle/opm projections (gemm share)
                    ;
                (t, self.tp_comm_bytes(cfg, n_eff, elem))
            }
        };
        let mult_c = if training { 3.0 } else { 1.0 };
        let mult_m = if training { 2.0 } else { 1.0 };
        let compute = compute * mult_c;
        let mut comm = 0.0;
        let mut overlappable = 0.0;
        for (bytes, can_overlap) in &comms {
            let t = self.intra.time(*bytes as usize) * mult_m;
            comm += t;
            if *can_overlap {
                overlappable += t;
            }
        }
        let exposed = if overlap {
            // overlappable collectives hide behind independent compute,
            // bounded by the compute actually available to hide behind
            let hidden = overlappable.min(0.5 * compute);
            comm - hidden
        } else {
            comm
        };
        StepTime { compute, comm, exposed_comm: exposed }
    }

    /// Full training-step time (per sample on the MP group), all blocks +
    /// recycling.
    pub fn train_step(
        &self,
        cfg: &ModelConfig,
        p: &ImplProfile,
        method: MpMethod,
        n: usize,
        overlap: bool,
    ) -> StepTime {
        let fwd = self.mp_block_time(cfg, p, method, n, false, overlap);
        let both = self.mp_block_time(cfg, p, method, n, true, overlap);
        let blocks = cfg.n_blocks as f64 * self.pipeline_mult;
        // (recycles−1) forward-only passes + 1 fwd+bwd pass
        StepTime {
            compute: blocks * ((TRAIN_RECYCLES - 1.0) * fwd.compute + both.compute),
            comm: blocks * ((TRAIN_RECYCLES - 1.0) * fwd.comm + both.comm),
            exposed_comm: blocks
                * ((TRAIN_RECYCLES - 1.0) * fwd.exposed_comm + both.exposed_comm),
        }
    }

    /// Data-parallel scaling on top of a fixed MP step: gradient ring
    /// all-reduce over the inter-node link (4 ranks share a NIC) +
    /// straggler penalty (max of n i.i.d. step-time jitters).
    pub fn dp_step(&self, cfg: &ModelConfig, mp_step_secs: f64, dp_ranks: usize) -> f64 {
        if dp_ranks <= 1 {
            return mp_step_secs;
        }
        let grad_bytes = cfg.param_count() as f64 * 4.0; // f32 grads
        let n = dp_ranks as f64;
        let ring = 2.0 * (n - 1.0) / n;
        let nic_share = 4.0_f64.min(n); // 4 GPUs per node share one HCA
        let allreduce = grad_bytes * ring / (self.inter.beta / nic_share)
            + self.inter.alpha * 2.0 * (n - 1.0);
        // DDP bucket overlap hides most of the all-reduce behind backward
        let exposed = allreduce * 0.35;
        // straggler: E[max of n N(0,σ)] ≈ σ √(2 ln n), σ = 1.5% of step
        let sigma = 0.015 * mp_step_secs;
        let straggler = if n > 1.0 { sigma * (2.0 * n.ln()).sqrt() } else { 0.0 };
        mp_step_secs + exposed + straggler
    }

    /// End-to-end inference latency for a sequence of length `n_res`
    /// (INFER_RECYCLES forward passes; `chunk` slows the baselines by extra
    /// kernel-launch + re-read overhead).
    pub fn inference_latency(
        &self,
        n_res: usize,
        p: &ImplProfile,
        method: MpMethod,
        n_gpus: usize,
        chunked: bool,
    ) -> f64 {
        let cfg = ModelConfig::inference(n_res);
        let t = self.mp_block_time(&cfg, p, method, n_gpus, false, true);
        let chunk_penalty = if chunked { 1.30 } else { 1.0 };
        cfg.n_blocks as f64 * self.pipeline_mult * t.total() * INFER_RECYCLES
            * chunk_penalty
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dap_beats_tp_scaling() {
        // Fig 10 shape: at n=4, DAP efficiency > TP efficiency
        let m = ScalingModel::default();
        let cfg = ModelConfig::finetune();
        let p = ImplProfile::fastfold();
        let t1 = m.train_step(&cfg, &p, MpMethod::Dap, 1, true).total();
        let d4 = m.train_step(&cfg, &p, MpMethod::Dap, 4, true).total();
        let t4 = m.train_step(&cfg, &p, MpMethod::TensorParallel, 4, true).total();
        let eff_dap = t1 / (4.0 * d4);
        let eff_tp = t1 / (4.0 * t4);
        assert!(eff_dap > eff_tp, "dap {eff_dap} vs tp {eff_tp}");
        assert!(eff_dap > 0.6, "dap eff {eff_dap}");
    }

    #[test]
    fn finetune_scales_better_than_initial() {
        // paper: initial training scales worse (smaller tensors, comm
        // overhead proportionally larger)
        let m = ScalingModel::default();
        let p = ImplProfile::fastfold();
        let eff = |cfg: &ModelConfig| {
            let t1 = m.train_step(cfg, &p, MpMethod::Dap, 1, true).total();
            let t4 = m.train_step(cfg, &p, MpMethod::Dap, 4, true).total();
            t1 / (4.0 * t4)
        };
        let e_init = eff(&ModelConfig::initial_training());
        let e_ft = eff(&ModelConfig::finetune());
        assert!(e_ft > e_init, "ft {e_ft} vs init {e_init}");
    }

    #[test]
    fn overlap_reduces_exposed_comm() {
        let m = ScalingModel::default();
        let cfg = ModelConfig::initial_training();
        let p = ImplProfile::fastfold();
        let on = m.train_step(&cfg, &p, MpMethod::Dap, 4, true);
        let off = m.train_step(&cfg, &p, MpMethod::Dap, 4, false);
        assert!(on.exposed_comm < off.exposed_comm);
        assert!(on.total() < off.total());
    }

    #[test]
    fn dp_efficiency_near_paper() {
        // paper Fig 11: 90.1% at 128-node fine-tuning
        let m = ScalingModel::default();
        let cfg = ModelConfig::finetune();
        let p = ImplProfile::fastfold();
        let step = m.train_step(&cfg, &p, MpMethod::Dap, 4, true).total();
        let t128 = m.dp_step(&cfg, step, 128);
        let eff = step / t128;
        assert!(eff > 0.82 && eff < 0.97, "dp eff {eff}");
    }

    #[test]
    fn tp_capped_at_pair_heads() {
        let m = ScalingModel::default();
        let cfg = ModelConfig::finetune();
        let p = ImplProfile::fastfold();
        let t4 = m.train_step(&cfg, &p, MpMethod::TensorParallel, 4, true).total();
        let t8 = m.train_step(&cfg, &p, MpMethod::TensorParallel, 8, true).total();
        // degree 8 collapses to 4: no further speedup
        assert!((t8 - t4).abs() / t4 < 0.05);
    }

    #[test]
    fn long_sequence_speedup_band() {
        // Fig 13: FastFold distributed vs OpenFold chunked ≈ 7.5–9.5×
        let m = ScalingModel::default();
        for &len in &[1024usize, 1536, 2048, 2560] {
            let of = m.inference_latency(
                len, &ImplProfile::openfold(), MpMethod::Dap, 1, true);
            let ff = m.inference_latency(
                len, &ImplProfile::fastfold(), MpMethod::Dap, 8, false);
            let speedup = of / ff;
            assert!(
                speedup > 5.0 && speedup < 13.0,
                "len {len}: speedup {speedup}"
            );
        }
    }
}
