//! Step-time scaling model: combines FLOP counts, implementation profiles,
//! link models, and measured collective volumes into the paper's scaling
//! curves (Figs 10–13, Tables IV–V). Shapes, not absolute numbers — see
//! DESIGN.md §2 and EXPERIMENTS.md for paper-vs-model comparisons.

use super::flops::{block_flops, BlockFlops};
use super::gpu::{GpuSpec, ImplProfile};
use crate::config::ModelConfig;
use crate::dap::CommCost;

/// Mean recycling passes during training (uniform 1..4 → extra forwards)
/// and fixed 4 at inference (paper §II.A).
pub const TRAIN_RECYCLES: f64 = 2.5;
pub const INFER_RECYCLES: f64 = 4.0;

#[derive(Clone, Copy, Debug, Default)]
pub struct StepTime {
    pub compute: f64,
    pub comm: f64,
    /// comm left exposed after computation–communication overlap
    pub exposed_comm: f64,
}

impl StepTime {
    pub fn total(&self) -> f64 {
        self.compute + self.exposed_comm
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MpMethod {
    Dap,
    TensorParallel,
}

/// Modeled economics of one hybrid DP×DAP training step
/// ([`ScalingModel::hybrid_step`]).
#[derive(Clone, Copy, Debug)]
pub struct HybridStep {
    /// DAP degree inside each replica
    pub dap: usize,
    /// data-parallel replicas
    pub dp: usize,
    /// end-to-end step seconds (MP step + exposed DP reduction +
    /// straggler)
    pub step_secs: f64,
    /// the DAP group's step seconds before DP costs
    pub mp_step_secs: f64,
    /// global samples per second (dp / step)
    pub samples_per_sec: f64,
    /// aggregate modeled PFLOP/s across the fleet — the paper's
    /// "6.02 PetaFLOPS at 512 GPUs" framing
    pub aggregate_pflops: f64,
    /// data-parallel scaling efficiency mp/step — the paper's Fig 11
    /// "90.1% at 128 nodes" number
    pub dp_efficiency: f64,
    /// throughput vs `gpus` ideal single-GPU copies (also absorbs the
    /// model-parallel efficiency loss inside each replica)
    pub end_to_end_efficiency: f64,
}

impl HybridStep {
    /// Total ranks the layout occupies.
    pub fn gpus(&self) -> usize {
        self.dap * self.dp
    }
}

#[derive(Clone, Copy, Debug)]
pub struct ScalingModel {
    pub gpu: GpuSpec,
    pub intra: CommCost,
    pub inter: CommCost,
    /// Whole-pipeline structural multiplier: this model prices the
    /// Evoformer trunk (48 blocks at the Table I cluster sizes); the real
    /// AlphaFold step also runs the extra-MSA stack (~5120 sequences),
    /// template stack, structure module and input pipeline. Calibrated
    /// ONCE against OpenFold's published initial-training step (6.186 s,
    /// paper Table IV) and applied uniformly — it cancels out of every
    /// ratio (speedups, efficiencies) and only anchors absolute seconds.
    pub pipeline_mult: f64,
}

impl Default for ScalingModel {
    fn default() -> Self {
        ScalingModel {
            gpu: GpuSpec::a100_40g(),
            intra: CommCost::nvlink(),
            inter: CommCost::infiniband(),
            pipeline_mult: 6.2,
        }
    }
}

/// Knobs of the overlap-aware data-parallel reduction model — the modeled
/// twin of the executed bucketed all-reduce (`train::bucket`): gradient
/// wire precision, bucket count, and NIC sharing.
#[derive(Clone, Copy, Debug)]
pub struct DpOverlap {
    /// wire bytes per gradient element (4 = f32, 2 = bf16)
    pub wire_bytes: f64,
    /// gradient buckets launched as the backward tape replay retires them
    pub n_buckets: usize,
    /// GPUs sharing one NIC (4 on A100 nodes; 1 on DGX-H100-class nodes
    /// with a 400G HCA per GPU)
    pub nic_share: f64,
}

impl DpOverlap {
    /// The legacy layout: one post-backward f32 all-reduce, A100 NIC
    /// sharing — nothing overlaps.
    pub fn f32_monolithic() -> Self {
        DpOverlap { wire_bytes: 4.0, n_buckets: 1, nic_share: 4.0 }
    }

    /// This PR's executed configuration on the A100 fleet model: bf16
    /// wire, backward-ordered buckets.
    pub fn bf16_bucketed() -> Self {
        DpOverlap { wire_bytes: 2.0, n_buckets: 24, nic_share: 4.0 }
    }
}

/// Modeled outcome of one overlapped DP reduction
/// ([`ScalingModel::dp_step_overlapped`]).
#[derive(Clone, Copy, Debug)]
pub struct DpStepModel {
    /// end-to-end step seconds (MP step + exposed reduction + straggler)
    pub step_secs: f64,
    /// total ring all-reduce seconds (bandwidth + per-bucket launches)
    pub allreduce_secs: f64,
    /// reduction seconds left exposed after hiding behind the backward
    pub exposed_secs: f64,
    /// 1 − exposed/allreduce — the `BENCH_train.json` gate metric
    pub overlap_fraction: f64,
}

impl ScalingModel {
    /// Compute time of one block forward on one device given the module
    /// FLOPs it actually executes.
    fn block_compute(&self, f: &BlockFlops, p: &ImplProfile, elem_bytes: f64) -> f64 {
        let t_gemm = (f.gemm + f.attention + f.triangle + f.opm)
            / (self.gpu.peak_flops * p.mxu_eff);
        let t_reduce = f.batch_reduce_elems * elem_bytes * p.reduce_passes / self.gpu.hbm_bw;
        let t_elem = f.elementwise_elems * elem_bytes * p.elem_passes / self.gpu.hbm_bw;
        t_gemm + t_reduce + t_elem
    }

    /// DAP per-block forward comm volume per rank (mirrors the manifest
    /// schedule: 5 gathers, 1 reduce-scatter, 4 all-to-alls).
    pub fn dap_comm_bytes(&self, cfg: &ModelConfig, n: usize, elem_bytes: f64) -> Vec<(f64, bool)> {
        if n <= 1 {
            return vec![];
        }
        let s = cfg.n_seq as f64;
        let r = cfg.n_res as f64;
        let nf = n as f64;
        let frac = (nf - 1.0) / nf;
        // (bytes, overlappable?) per collective
        let mut v = Vec::new();
        let gather = |full_elems: f64| full_elems * elem_bytes * frac;
        // bias gathers (row, tri-start, tri-end): full (r,r,h)
        v.push((gather(r * r * cfg.n_heads_msa as f64), true));
        v.push((gather(r * r * cfg.n_heads_pair as f64), true));
        v.push((gather(r * r * cfg.n_heads_pair as f64), true));
        // OPM right-projection gather: (s, r, d_opm)
        v.push((gather(s * r * cfg.d_opm as f64), true));
        // triangle-out b gather: (r, r, dz)
        v.push((gather(r * r * cfg.d_pair as f64), false));
        // triangle-in reduce-scatter: (r, r, dz) partial
        v.push((r * r * cfg.d_pair as f64 * elem_bytes * frac, false));
        // 4 × all_to_all: local tensor × (n-1)/n — m twice, z twice
        let m_local = s * r * cfg.d_msa as f64 / nf;
        let z_local = r * r * cfg.d_pair as f64 / nf;
        v.push((m_local * elem_bytes * frac, false));
        v.push((m_local * elem_bytes * frac, true)); // a2a_m overlaps pair stack
        v.push((z_local * elem_bytes * frac, false));
        v.push((z_local * elem_bytes * frac, false));
        v
    }

    /// TP per-block forward comm: 6 AllReduce of full intermediates
    /// (paper Table III), ring volume 2(n−1)/n each. None overlappable.
    pub fn tp_comm_bytes(&self, cfg: &ModelConfig, n: usize, elem_bytes: f64) -> Vec<(f64, bool)> {
        if n <= 1 {
            return vec![];
        }
        let s = cfg.n_seq as f64;
        let r = cfg.n_res as f64;
        let ring = 2.0 * (n as f64 - 1.0) / n as f64;
        let msa = s * r * cfg.d_msa as f64 * elem_bytes * ring;
        let pair = r * r * cfg.d_pair as f64 * elem_bytes * ring;
        vec![
            (msa, false), // row attn out
            (msa, false), // col attn out
            (msa, false), // msa transition
            (pair, false), // tri start attn
            (pair, false), // tri end attn
            (pair, false), // pair transition
        ]
    }

    /// Model-parallel step time per block-forward at degree `n`.
    /// `training` doubles comm (bwd collectives) and triples compute
    /// (fwd+bwd); Duality-Async overlap hides overlappable collectives
    /// behind compute when `overlap`.
    pub fn mp_block_time(
        &self,
        cfg: &ModelConfig,
        p: &ImplProfile,
        method: MpMethod,
        n: usize,
        training: bool,
        overlap: bool,
    ) -> StepTime {
        let elem = 2.0; // bf16
        let f = block_flops(cfg, cfg.n_seq, cfg.n_res);
        let nf = n as f64;
        let compute_1 = self.block_compute(&f, p, elem);
        let (compute, comms) = match method {
            MpMethod::Dap => {
                // every module parallelizes: 1/n compute per rank
                (compute_1 / nf, self.dap_comm_bytes(cfg, n, elem))
            }
            MpMethod::TensorParallel => {
                // only attention+FF parallelize; triangle-mult + OPM are
                // replicated (paper §IV.B.1); TP degree capped at pair heads
                let n_eff = n.min(cfg.n_heads_pair);
                let nf_eff = n_eff as f64;
                let repl = BlockFlops { triangle: f.triangle, opm: f.opm, ..Default::default() };
                let par = BlockFlops {
                    gemm: f.gemm,
                    attention: f.attention,
                    // batch-reduce & elementwise follow their tensors
                    batch_reduce_elems: f.batch_reduce_elems,
                    elementwise_elems: f.elementwise_elems,
                    ..Default::default()
                };
                let t = self.block_compute(&par, p, elem) / nf_eff
                    + self.block_compute(&repl, p, elem)
                    // replicated triangle/opm projections (gemm share)
                    ;
                (t, self.tp_comm_bytes(cfg, n_eff, elem))
            }
        };
        let mult_c = if training { 3.0 } else { 1.0 };
        let mult_m = if training { 2.0 } else { 1.0 };
        let compute = compute * mult_c;
        let mut comm = 0.0;
        let mut overlappable = 0.0;
        for (bytes, can_overlap) in &comms {
            let t = self.intra.time(*bytes as usize) * mult_m;
            comm += t;
            if *can_overlap {
                overlappable += t;
            }
        }
        let exposed = if overlap {
            // overlappable collectives hide behind independent compute,
            // bounded by the compute actually available to hide behind
            let hidden = overlappable.min(0.5 * compute);
            comm - hidden
        } else {
            comm
        };
        StepTime { compute, comm, exposed_comm: exposed }
    }

    /// Full training-step time (per sample on the MP group), all blocks +
    /// recycling.
    pub fn train_step(
        &self,
        cfg: &ModelConfig,
        p: &ImplProfile,
        method: MpMethod,
        n: usize,
        overlap: bool,
    ) -> StepTime {
        let fwd = self.mp_block_time(cfg, p, method, n, false, overlap);
        let both = self.mp_block_time(cfg, p, method, n, true, overlap);
        let blocks = cfg.n_blocks as f64 * self.pipeline_mult;
        // (recycles−1) forward-only passes + 1 fwd+bwd pass
        StepTime {
            compute: blocks * ((TRAIN_RECYCLES - 1.0) * fwd.compute + both.compute),
            comm: blocks * ((TRAIN_RECYCLES - 1.0) * fwd.comm + both.comm),
            exposed_comm: blocks
                * ((TRAIN_RECYCLES - 1.0) * fwd.exposed_comm + both.exposed_comm),
        }
    }

    /// Data-parallel scaling on top of a fixed MP step: gradient ring
    /// all-reduce over the inter-node link (4 ranks share a NIC) +
    /// straggler penalty (max of n i.i.d. step-time jitters).
    pub fn dp_step(&self, cfg: &ModelConfig, mp_step_secs: f64, dp_ranks: usize) -> f64 {
        if dp_ranks <= 1 {
            return mp_step_secs;
        }
        let grad_bytes = cfg.param_count() as f64 * 4.0; // f32 grads
        let n = dp_ranks as f64;
        let ring = 2.0 * (n - 1.0) / n;
        let nic_share = 4.0_f64.min(n); // 4 GPUs per node share one HCA
        let allreduce = grad_bytes * ring / (self.inter.beta / nic_share)
            + self.inter.alpha * 2.0 * (n - 1.0);
        // DDP bucket overlap hides most of the all-reduce behind backward
        let exposed = allreduce * 0.35;
        // straggler: E[max of n N(0,σ)] ≈ σ √(2 ln n), σ = 1.5% of step
        let sigma = 0.015 * mp_step_secs;
        let straggler = if n > 1.0 { sigma * (2.0 * n.ln()).sqrt() } else { 0.0 };
        mp_step_secs + exposed + straggler
    }

    /// Overlap-aware refinement of [`ScalingModel::dp_step`]: instead of
    /// the fixed 0.35 DDP exposure factor, model the bucketed all-reduce
    /// this PR executes. Buckets launch as the backward tape replay
    /// retires their leaves, so the reduction can hide behind the
    /// remaining backward compute — backward is 2 of the
    /// `TRAIN_RECYCLES + 2` compute passes of a step, and the first
    /// bucket's gradients only exist after `1/B` of it. The last bucket
    /// necessarily runs after the backward finishes, so at least
    /// `allreduce/B` stays exposed; each bucket pays its own ring launch
    /// latency (the α·2(n−1) term × B).
    pub fn dp_step_overlapped(
        &self,
        cfg: &ModelConfig,
        mp_step_secs: f64,
        dp_ranks: usize,
        ov: DpOverlap,
    ) -> DpStepModel {
        if dp_ranks <= 1 {
            return DpStepModel {
                step_secs: mp_step_secs,
                allreduce_secs: 0.0,
                exposed_secs: 0.0,
                overlap_fraction: 1.0,
            };
        }
        let b = ov.n_buckets.max(1) as f64;
        let grad_bytes = cfg.param_count() as f64 * ov.wire_bytes;
        let n = dp_ranks as f64;
        let ring = 2.0 * (n - 1.0) / n;
        let allreduce = grad_bytes * ring / (self.inter.beta / ov.nic_share)
            + self.inter.alpha * 2.0 * (n - 1.0) * b;
        let bwd = mp_step_secs * 2.0 / (TRAIN_RECYCLES + 2.0);
        let window = bwd * (1.0 - 1.0 / b);
        let exposed = (allreduce - window).max(allreduce / b).min(allreduce);
        let sigma = 0.015 * mp_step_secs;
        let straggler = sigma * (2.0 * n.ln()).sqrt();
        DpStepModel {
            step_secs: mp_step_secs + exposed + straggler,
            allreduce_secs: allreduce,
            exposed_secs: exposed,
            overlap_fraction: 1.0 - exposed / allreduce,
        }
    }

    /// [`ScalingModel::phase_hours`] with the overlapped DP reduction in
    /// place of the legacy fixed-factor model.
    pub fn phase_hours_overlapped(
        &self,
        cfg: &ModelConfig,
        p: &ImplProfile,
        dap: usize,
        dp: usize,
        samples: f64,
        ov: DpOverlap,
    ) -> f64 {
        let mp = self.train_step(cfg, p, MpMethod::Dap, dap, true).total();
        let d = self.dp_step_overlapped(cfg, mp, dp, ov);
        d.step_secs * (samples / dp.max(1) as f64) / 3600.0
    }

    /// An H100 fleet (the ScaleFold platform): NVLink4 intra-node (900
    /// GB/s nominal; ~270 GB/s effective collective busbw at Evoformer
    /// message sizes), NDR InfiniBand inter-node with one 400G HCA per
    /// GPU (50 GB/s each, so `nic_share = 1`). The structural
    /// `pipeline_mult` carries over unchanged — it prices the model, not
    /// the device.
    pub fn h100_cluster() -> Self {
        ScalingModel {
            gpu: GpuSpec::h100_80g(),
            intra: CommCost { alpha: 10e-6, beta: 270e9 },
            inter: CommCost { alpha: 8e-6, beta: 50e9 },
            pipeline_mult: 6.2,
        }
    }

    /// The second calibration point next to FastFold's 67 h: ScaleFold
    /// (arXiv:2404.11068) reports AlphaFold pretraining compressed from
    /// 7.51 days to ~10.3 h on 2080 H100s. Modeled as the two-stage
    /// recipe at the fixed global batch of 128 on the
    /// [`ScalingModel::h100_cluster`]: the initial stage at dap=8 ×
    /// dp=128 (1024 ranks), fine-tuning at dap=16 × dp=128 (2048 of the
    /// 2080-GPU fleet), with the bf16 gradient wire and 24-bucket
    /// overlapped all-reduce this PR executes. Returns (initial hours,
    /// finetune hours); the sum lands within 10% of the 10.3-h headline
    /// (tested below).
    pub fn scalefold_hours() -> (f64, f64) {
        let m = Self::h100_cluster();
        let p = ImplProfile::scalefold();
        let ov = DpOverlap { wire_bytes: 2.0, n_buckets: 24, nic_share: 1.0 };
        let hi = m.phase_hours_overlapped(
            &ModelConfig::initial_training(),
            &p,
            8,
            128,
            10.0e6,
            ov,
        );
        let hf =
            m.phase_hours_overlapped(&ModelConfig::finetune(), &p, 16, 128, 1.5e6, ov);
        (hi, hf)
    }

    /// One hybrid DP×DAP training step at paper scale: the DAP group's
    /// model-parallel step ([`ScalingModel::train_step`]) composed with
    /// the DP ring/straggler model ([`ScalingModel::dp_step`]), plus the
    /// throughput/efficiency bookkeeping `fastfold scale` and the
    /// Table IV bench report.
    pub fn hybrid_step(
        &self,
        cfg: &ModelConfig,
        p: &ImplProfile,
        dap: usize,
        dp: usize,
        overlap: bool,
    ) -> HybridStep {
        let mp = self.train_step(cfg, p, MpMethod::Dap, dap, overlap).total();
        let step = self.dp_step(cfg, mp, dp);
        let t1 = self.train_step(cfg, p, MpMethod::Dap, 1, overlap).total();
        let gpus = dap * dp;
        let samples_per_sec = dp as f64 / step;
        let flops =
            super::flops::train_step_flops(cfg, TRAIN_RECYCLES) * dp as f64;
        HybridStep {
            dap,
            dp,
            step_secs: step,
            mp_step_secs: mp,
            samples_per_sec,
            aggregate_pflops: flops / step / 1e15,
            dp_efficiency: mp / step,
            end_to_end_efficiency: samples_per_sec / (gpus as f64 / t1),
        }
    }

    /// Wall hours for a training phase of `samples` samples under one
    /// hybrid step layout. The model's global batch per optimizer step is
    /// `dp` (one sample per replica per step — the same convention as
    /// [`HybridStep::samples_per_sec`]), so fewer replicas honestly means
    /// more steps, not cheaper hours.
    pub fn phase_hours(
        &self,
        cfg: &ModelConfig,
        p: &ImplProfile,
        dap: usize,
        dp: usize,
        samples: f64,
    ) -> f64 {
        let step = self.hybrid_step(cfg, p, dap, dp, true).step_secs;
        step * (samples / dp.max(1) as f64) / 3600.0
    }

    /// The paper's end-to-end Table IV scenario: 10M initial-training
    /// samples at (dap, dp) = `init`, then 1.5M fine-tuning samples at
    /// `ft` (the paper's layouts use dp = 128, i.e. global batch 128).
    /// Returns (initial hours, finetune hours) — the FastFold layout sums
    /// to the ~67-hour headline.
    pub fn two_stage_hours(
        &self,
        p: &ImplProfile,
        init: (usize, usize),
        ft: (usize, usize),
    ) -> (f64, f64) {
        let h_init = self.phase_hours(
            &ModelConfig::initial_training(),
            p,
            init.0,
            init.1,
            10.0e6,
        );
        let h_ft = self.phase_hours(&ModelConfig::finetune(), p, ft.0, ft.1, 1.5e6);
        (h_init, h_ft)
    }

    /// End-to-end inference latency for a sequence of length `n_res`
    /// (INFER_RECYCLES forward passes; `chunk` slows the baselines by extra
    /// kernel-launch + re-read overhead).
    pub fn inference_latency(
        &self,
        n_res: usize,
        p: &ImplProfile,
        method: MpMethod,
        n_gpus: usize,
        chunked: bool,
    ) -> f64 {
        let cfg = ModelConfig::inference(n_res);
        let t = self.mp_block_time(&cfg, p, method, n_gpus, false, true);
        let chunk_penalty = if chunked { 1.30 } else { 1.0 };
        cfg.n_blocks as f64 * self.pipeline_mult * t.total() * INFER_RECYCLES
            * chunk_penalty
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dap_beats_tp_scaling() {
        // Fig 10 shape: at n=4, DAP efficiency > TP efficiency
        let m = ScalingModel::default();
        let cfg = ModelConfig::finetune();
        let p = ImplProfile::fastfold();
        let t1 = m.train_step(&cfg, &p, MpMethod::Dap, 1, true).total();
        let d4 = m.train_step(&cfg, &p, MpMethod::Dap, 4, true).total();
        let t4 = m.train_step(&cfg, &p, MpMethod::TensorParallel, 4, true).total();
        let eff_dap = t1 / (4.0 * d4);
        let eff_tp = t1 / (4.0 * t4);
        assert!(eff_dap > eff_tp, "dap {eff_dap} vs tp {eff_tp}");
        assert!(eff_dap > 0.6, "dap eff {eff_dap}");
    }

    #[test]
    fn finetune_scales_better_than_initial() {
        // paper: initial training scales worse (smaller tensors, comm
        // overhead proportionally larger)
        let m = ScalingModel::default();
        let p = ImplProfile::fastfold();
        let eff = |cfg: &ModelConfig| {
            let t1 = m.train_step(cfg, &p, MpMethod::Dap, 1, true).total();
            let t4 = m.train_step(cfg, &p, MpMethod::Dap, 4, true).total();
            t1 / (4.0 * t4)
        };
        let e_init = eff(&ModelConfig::initial_training());
        let e_ft = eff(&ModelConfig::finetune());
        assert!(e_ft > e_init, "ft {e_ft} vs init {e_init}");
    }

    #[test]
    fn overlap_reduces_exposed_comm() {
        let m = ScalingModel::default();
        let cfg = ModelConfig::initial_training();
        let p = ImplProfile::fastfold();
        let on = m.train_step(&cfg, &p, MpMethod::Dap, 4, true);
        let off = m.train_step(&cfg, &p, MpMethod::Dap, 4, false);
        assert!(on.exposed_comm < off.exposed_comm);
        assert!(on.total() < off.total());
    }

    #[test]
    fn dp_efficiency_near_paper() {
        // paper Fig 11: 90.1% at 128-node fine-tuning
        let m = ScalingModel::default();
        let cfg = ModelConfig::finetune();
        let p = ImplProfile::fastfold();
        let step = m.train_step(&cfg, &p, MpMethod::Dap, 4, true).total();
        let t128 = m.dp_step(&cfg, step, 128);
        let eff = step / t128;
        assert!(eff > 0.82 && eff < 0.97, "dp eff {eff}");
    }

    #[test]
    fn hybrid_512_gpu_headline() {
        // paper Table IV: fine-tuning on 512 A100 (dap=4 × dp=128) runs at
        // 6.02 aggregate PFLOP/s with 90.1% DP efficiency
        let m = ScalingModel::default();
        let p = ImplProfile::fastfold();
        let h = m.hybrid_step(&ModelConfig::finetune(), &p, 4, 128, true);
        assert_eq!(h.gpus(), 512);
        assert!(
            h.aggregate_pflops > 5.0 && h.aggregate_pflops < 7.0,
            "aggregate {:.2} PFLOP/s",
            h.aggregate_pflops
        );
        assert!(
            h.dp_efficiency > 0.90 && h.dp_efficiency < 0.98,
            "dp efficiency {:.3}",
            h.dp_efficiency
        );
        assert!(h.end_to_end_efficiency > 0.5 && h.end_to_end_efficiency < 1.0);
        assert!(h.mp_step_secs < h.step_secs);
        // sanity: samples/s is dp / step
        assert!((h.samples_per_sec - 128.0 / h.step_secs).abs() < 1e-9);
    }

    #[test]
    fn hybrid_efficiency_degrades_gracefully_with_dp() {
        let m = ScalingModel::default();
        let p = ImplProfile::fastfold();
        let cfg = ModelConfig::finetune();
        let mut prev = f64::INFINITY;
        for dp in [1usize, 8, 32, 128] {
            let h = m.hybrid_step(&cfg, &p, 4, dp, true);
            assert!(h.dp_efficiency <= prev + 1e-12, "dp={dp}");
            assert!(h.dp_efficiency > 0.85, "dp={dp}: {}", h.dp_efficiency);
            prev = h.dp_efficiency;
        }
    }

    #[test]
    fn two_stage_total_reproduces_67_hours() {
        // paper headline: 11 days (OpenFold-class) -> ~67 hours (FastFold:
        // dap=2×dp=128 initial, dap=4×dp=128 finetune)
        let m = ScalingModel::default();
        let (hi, hf) = m.two_stage_hours(&ImplProfile::fastfold(), (2, 128), (4, 128));
        let total = hi + hf;
        assert!(total > 55.0 && total < 80.0, "total {total:.1} h");
        assert!(hi > hf, "initial phase dominates: {hi:.1} vs {hf:.1}");
        // the OpenFold baseline (dense replicas) lands in the ~8.4-day band
        let (oi, of) = m.two_stage_hours(&ImplProfile::openfold(), (1, 128), (1, 128));
        let baseline_days = (oi + of) / 24.0;
        assert!(
            baseline_days > 6.0 && baseline_days < 11.0,
            "baseline {baseline_days:.2} days"
        );
        // and the speedup is the paper's ~3x economics
        assert!((oi + of) / total > 2.0, "speedup {:.2}", (oi + of) / total);
        // hours scale honestly with the replica count (global batch = dp):
        // half the replicas ≈ twice the wall-clock, not half the cost
        let (hi64, _) = m.two_stage_hours(&ImplProfile::fastfold(), (2, 64), (4, 64));
        assert!(
            hi64 > 1.8 * hi && hi64 < 2.2 * hi,
            "dp=64 initial {hi64:.1} h vs dp=128 {hi:.1} h"
        );
    }

    #[test]
    fn bucketed_overlap_beats_fixed_ddp_factor() {
        // the overlap-aware model must (a) reduce to full exposure for a
        // single post-backward bucket and (b) hide more than the legacy
        // 0.35 factor once buckets launch from the backward tape
        let m = ScalingModel::default();
        let cfg = ModelConfig::finetune();
        let p = ImplProfile::fastfold();
        let mp = m.train_step(&cfg, &p, MpMethod::Dap, 4, true).total();
        let mono = m.dp_step_overlapped(&cfg, mp, 128, DpOverlap::f32_monolithic());
        assert!((mono.exposed_secs - mono.allreduce_secs).abs() < 1e-12);
        assert!(mono.overlap_fraction.abs() < 1e-12);
        let b = m.dp_step_overlapped(
            &cfg,
            mp,
            128,
            DpOverlap { wire_bytes: 4.0, n_buckets: 24, nic_share: 4.0 },
        );
        assert!(b.exposed_secs < 0.35 * b.allreduce_secs, "exposed {}", b.exposed_secs);
        assert!(b.overlap_fraction > 0.5, "overlap {}", b.overlap_fraction);
        assert!(b.step_secs < mono.step_secs);
        // the legacy fixed-factor step stays between the two extremes
        let legacy = m.dp_step(&cfg, mp, 128);
        assert!(b.step_secs < legacy && legacy < mono.step_secs);
        // dp=1: nothing to reduce
        let solo = m.dp_step_overlapped(&cfg, mp, 1, DpOverlap::bf16_bucketed());
        assert_eq!(solo.step_secs, mp);
        assert_eq!(solo.overlap_fraction, 1.0);
    }

    #[test]
    fn bf16_wire_halves_bandwidth_term() {
        let m = ScalingModel::default();
        let cfg = ModelConfig::finetune();
        let p = ImplProfile::fastfold();
        let mp = m.train_step(&cfg, &p, MpMethod::Dap, 4, true).total();
        let f32w = m.dp_step_overlapped(
            &cfg,
            mp,
            128,
            DpOverlap { wire_bytes: 4.0, n_buckets: 24, nic_share: 4.0 },
        );
        let bf16 = m.dp_step_overlapped(&cfg, mp, 128, DpOverlap::bf16_bucketed());
        // half the wire bytes: the bandwidth term halves, launches do not
        assert!(bf16.allreduce_secs < f32w.allreduce_secs);
        assert!(bf16.allreduce_secs > 0.4 * f32w.allreduce_secs);
        assert!(bf16.step_secs <= f32w.step_secs);
    }

    #[test]
    fn scalefold_10_hours_on_h100() {
        // second calibration target (arXiv:2404.11068): ~10.3 h on 2080
        // H100s, from a 7.51-day baseline — modeled within 10%
        let (hi, hf) = ScalingModel::scalefold_hours();
        let total = hi + hf;
        assert!(
            (total - 10.3).abs() / 10.3 < 0.10,
            "scalefold total {total:.2} h (target 10.3 ± 10%)"
        );
        assert!(hi > hf, "initial phase dominates: {hi:.1} vs {hf:.1}");
        // and the A100 dense-replica baseline stays in the multi-day
        // band — the modeled compression matches the paper's ~17.5x
        let base = ScalingModel::default();
        let (oi, of) =
            base.two_stage_hours(&ImplProfile::openfold(), (1, 128), (1, 128));
        let speedup = (oi + of) / total;
        assert!(speedup > 15.0 && speedup < 30.0, "speedup {speedup:.1}x");
    }

    #[test]
    fn tp_capped_at_pair_heads() {
        let m = ScalingModel::default();
        let cfg = ModelConfig::finetune();
        let p = ImplProfile::fastfold();
        let t4 = m.train_step(&cfg, &p, MpMethod::TensorParallel, 4, true).total();
        let t8 = m.train_step(&cfg, &p, MpMethod::TensorParallel, 8, true).total();
        // degree 8 collapses to 4: no further speedup
        assert!((t8 - t4).abs() / t4 < 0.05);
    }

    #[test]
    fn long_sequence_speedup_band() {
        // Fig 13: FastFold distributed vs OpenFold chunked ≈ 7.5–9.5×
        let m = ScalingModel::default();
        for &len in &[1024usize, 1536, 2048, 2560] {
            let of = m.inference_latency(
                len, &ImplProfile::openfold(), MpMethod::Dap, 1, true);
            let ff = m.inference_latency(
                len, &ImplProfile::fastfold(), MpMethod::Dap, 8, false);
            let speedup = of / ff;
            assert!(
                speedup > 5.0 && speedup < 13.0,
                "len {len}: speedup {speedup}"
            );
        }
    }
}
