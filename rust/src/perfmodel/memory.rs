//! Activation-memory model → the Table V OOM boundary.
//!
//! The paper's §III.B headline: attention context memory scales as
//! N_r³ · N_head · sizeof(bf16) in the pair stack (> 20 GB at N_r = 384
//! over 48 layers). We model the peak *inference* working set per device:
//! representations + the largest transient per block (attention scores or
//! triangle intermediates), under chunking (baselines) or DAP sharding
//! (FastFold), and declare sim-OOM when it exceeds device capacity.

use crate::config::ModelConfig;
use crate::error::{Error, Result};

/// Bytes per bf16 element.
pub const BF16: f64 = 2.0;
/// Bytes per f32 element.
pub const F32: f64 = 4.0;

#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    /// bytes per element of activations
    pub elem_bytes: f64,
    /// framework/weights/workspace overhead per device (bytes)
    pub fixed_overhead: f64,
}

impl Default for MemoryModel {
    fn default() -> Self {
        // inference activations are f32 (OpenFold/AlphaFold default);
        // weights + framework context ≈ 2 GB
        MemoryModel { elem_bytes: F32, fixed_overhead: 2.0e9 }
    }
}

impl MemoryModel {
    /// Peak inference working set per device (bytes) — the **coarse**
    /// model: a single uniform chunk factor over the streamed attention
    /// transient. The AutoChunk planner uses the finer per-module model
    /// below ([`MemoryModel::module_transient_elems`]); this function is
    /// kept as the §V.C uniform-chunking baseline and for Table V.
    ///
    /// * `dap` — DAP degree (activations sharded 1/dap; transient attention
    ///   batch is over the local shard).
    /// * `chunk` — chunking factor along the batch axis of attention
    ///   (baseline path; 1 = no chunking). Chunking shrinks transients but
    ///   NOT the resident representations — that is why the baselines still
    ///   OOM at 3k+ (paper Table V).
    ///
    /// ```
    /// use fastfold::config::ModelConfig;
    /// use fastfold::perfmodel::MemoryModel;
    ///
    /// let mem = MemoryModel::default();
    /// let cfg = ModelConfig::inference(2048);
    /// let unchunked = mem.inference_peak(&cfg, 1, 1);
    /// let chunked = mem.inference_peak(&cfg, 1, 16);
    /// // chunking shrinks transients, but the resident reps remain
    /// assert!(chunked < unchunked);
    /// assert!(chunked > 0.1 * unchunked);
    /// ```
    pub fn inference_peak(&self, cfg: &ModelConfig, dap: usize, chunk: usize) -> f64 {
        let s = cfg.n_seq as f64;
        let r = cfg.n_res as f64;
        let dm = cfg.d_msa as f64;
        let dz = cfg.d_pair as f64;
        let hp = cfg.n_heads_pair as f64;
        let hm = cfg.n_heads_msa as f64;
        let dap = dap as f64;
        let chunk = chunk as f64;

        let _ = hp;
        // resident: m (+ residual copy) + z (2 working copies + the
        // recycling buffer AlphaFold keeps between recycle iterations)
        let resident = (2.0 * s * r * dm + 3.0 * r * r * dz) / dap;

        // largest transients per block:
        // attention scores for the processed batch slice (chunkable — the
        // chunking technique of §V.C targets exactly these):
        let msa_attn = (s / dap / chunk).max(1.0) * hm * r * r;
        // triangle-mult working set: left/right projections + gates + the
        // contraction output. NOT chunkable along the batch axis (the k
        // contraction needs the full axis) — this is what keeps the
        // baselines OOMing past ~3k residues even with chunking (Table V).
        let tri_mult = if dap > 1.0 {
            // local projections (4/dap) + gathered right operand (1) +
            // full incoming partial (1) + working copies (0.75)
            (4.0 / dap + 2.75) * r * r * dz
        } else {
            5.0 * r * r * dz
        };
        let transient = msa_attn.max(tri_mult);

        self.elem_bytes * (resident + transient) + self.fixed_overhead
    }

    /// The paper's §III.B training bound: storing row-attention context for
    /// backward across all blocks without checkpointing.
    pub fn attention_activation_all_blocks(&self, cfg: &ModelConfig) -> f64 {
        let r = cfg.n_res as f64;
        let h = cfg.n_heads_pair as f64;
        cfg.n_blocks as f64 * r * r * r * h * self.elem_bytes
    }

    /// Check an inference plan against device capacity.
    pub fn check(
        &self,
        cfg: &ModelConfig,
        dap: usize,
        chunk: usize,
        capacity: f64,
    ) -> Result<f64> {
        let need = self.inference_peak(cfg, dap, chunk);
        if need > capacity {
            Err(Error::SimOom { need_gb: need / 1e9, cap_gb: capacity / 1e9 })
        } else {
            Ok(need)
        }
    }
}

// ----------------------------------------------- fine-grained (per-module)

/// The transient-producing sub-modules of one Evoformer block, each with
/// its own chunkable axis — the strategy space the AutoChunk planner
/// ([`crate::inference::autochunk`]) searches per block.
///
/// The coarse [`MemoryModel::inference_peak`] collapses all of these into
/// one streamed attention term; this enum models what a *naive unchunked*
/// execution actually materializes per module, which is the baseline the
/// paper's ">80% inference memory reduction" claim (§IV AutoChunk) is
/// measured against.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum BlockModule {
    /// MSA row-wise gated attention: scores `(s, h_m, r, r)`, chunkable
    /// along the MSA-row axis `s`.
    MsaRowAttn,
    /// MSA column-wise attention: scores `(r, h_m, s, s)`, chunkable along
    /// the residue axis `r`.
    MsaColAttn,
    /// Outer-product mean: outer tensor `(r, r, d_opm²)` before the output
    /// projection, chunkable along the first residue axis.
    OuterProductMean,
    /// MSA transition MLP: hidden activations `(s, r, t·d_msa)`, chunkable
    /// along `s`.
    MsaTransition,
    /// Triangle multiplicative update (outgoing + incoming): projections,
    /// gates and the `ikc,jkc->ijc` contraction. **Not chunkable on a
    /// single device** — the contraction consumes the full `k` axis, which
    /// is exactly why the baselines still OOM past ~3k residues (Table V)
    /// while DAP keeps scaling.
    TriangleMult,
    /// Triangle attention around starting node: scores `(r, h_p, r, r)` —
    /// the §III.B cubic term — chunkable along the first residue axis.
    TriangleAttnStart,
    /// Triangle attention around ending node: same shape/axis as
    /// [`BlockModule::TriangleAttnStart`].
    TriangleAttnEnd,
    /// Pair transition MLP: hidden activations `(r, r, t·d_pair)`,
    /// chunkable along the first residue axis.
    PairTransition,
}

impl BlockModule {
    /// Every module, in schedule order.
    pub const ALL: [BlockModule; 8] = [
        BlockModule::MsaRowAttn,
        BlockModule::MsaColAttn,
        BlockModule::OuterProductMean,
        BlockModule::MsaTransition,
        BlockModule::TriangleMult,
        BlockModule::TriangleAttnStart,
        BlockModule::TriangleAttnEnd,
        BlockModule::PairTransition,
    ];

    /// Stable snake_case name (used by the `ChunkPlan` JSON encoding).
    pub fn name(self) -> &'static str {
        match self {
            BlockModule::MsaRowAttn => "msa_row_attn",
            BlockModule::MsaColAttn => "msa_col_attn",
            BlockModule::OuterProductMean => "outer_product_mean",
            BlockModule::MsaTransition => "msa_transition",
            BlockModule::TriangleMult => "triangle_mult",
            BlockModule::TriangleAttnStart => "triangle_attn_start",
            BlockModule::TriangleAttnEnd => "triangle_attn_end",
            BlockModule::PairTransition => "pair_transition",
        }
    }

    /// Inverse of [`BlockModule::name`].
    pub fn parse(s: &str) -> Result<Self> {
        BlockModule::ALL
            .into_iter()
            .find(|m| m.name() == s)
            .ok_or_else(|| Error::Config(format!("unknown block module '{s}'")))
    }

    /// Length of the axis the chunk loop iterates for this module on one
    /// device (after DAP sharding). `1` means the module is not chunkable
    /// (its transient is irreducible on a single device).
    pub fn chunk_axis_len(self, cfg: &ModelConfig, dap: usize) -> usize {
        let dap = dap.max(1);
        let s_loc = (cfg.n_seq + dap - 1) / dap;
        let r_loc = (cfg.n_res + dap - 1) / dap;
        match self {
            BlockModule::MsaRowAttn | BlockModule::MsaTransition => s_loc,
            BlockModule::MsaColAttn
            | BlockModule::OuterProductMean
            | BlockModule::TriangleAttnStart
            | BlockModule::TriangleAttnEnd
            | BlockModule::PairTransition => r_loc,
            BlockModule::TriangleMult => 1,
        }
    }
}

impl MemoryModel {
    /// Resident representation elements per device: m (+ residual copy) +
    /// z (2 working copies + the recycling buffer), sharded 1/dap.
    pub fn resident_elems(&self, cfg: &ModelConfig, dap: usize) -> f64 {
        let s = cfg.n_seq as f64;
        let r = cfg.n_res as f64;
        (2.0 * s * r * cfg.d_msa as f64 + 3.0 * r * r * cfg.d_pair as f64)
            / dap.max(1) as f64
    }

    /// Peak transient elements `module` materializes on one device when its
    /// chunk axis is split into `chunks` pieces (1 = unchunked). Monotone
    /// nonincreasing in `chunks`, monotone nondecreasing in `cfg.n_res`.
    pub fn module_transient_elems(
        &self,
        cfg: &ModelConfig,
        module: BlockModule,
        dap: usize,
        chunks: usize,
    ) -> f64 {
        let dap = dap.max(1);
        let chunks = chunks.max(1);
        let s = cfg.n_seq as f64;
        let r = cfg.n_res as f64;
        let hm = cfg.n_heads_msa as f64;
        let hp = cfg.n_heads_pair as f64;
        let dz = cfg.d_pair as f64;
        let t = cfg.transition_factor as f64;
        let axis = module.chunk_axis_len(cfg, dap);
        // rows of the chunk axis processed at once (chunk counts beyond the
        // axis length clamp to one row per chunk)
        let c = chunks.min(axis).max(1);
        let rows = ((axis + c - 1) / c) as f64;
        match module {
            BlockModule::MsaRowAttn => rows * hm * r * r,
            BlockModule::MsaColAttn => rows * hm * s * s,
            BlockModule::OuterProductMean => {
                rows * r * (cfg.d_opm * cfg.d_opm) as f64
            }
            BlockModule::MsaTransition => rows * r * t * cfg.d_msa as f64,
            BlockModule::TriangleMult => {
                // same irreducible working set as the coarse model: under
                // DAP the projections shard but the gathered right operand
                // + incoming partial + working copies do not; on a single
                // device everything is live at the contraction.
                if dap > 1 {
                    (4.0 / dap as f64 + 2.75) * r * r * dz
                } else {
                    5.0 * r * r * dz
                }
            }
            BlockModule::TriangleAttnStart | BlockModule::TriangleAttnEnd => {
                rows * hp * r * r
            }
            BlockModule::PairTransition => rows * r * t * dz,
        }
    }

    /// Peak bytes of a per-module chunk assignment: resident + the largest
    /// module transient under its assigned chunk count, plus overhead.
    /// Modules absent from `assignment` are priced unchunked.
    pub fn planned_peak_bytes(
        &self,
        cfg: &ModelConfig,
        dap: usize,
        assignment: &[(BlockModule, usize)],
    ) -> f64 {
        let chunks_of = |m: BlockModule| -> usize {
            assignment
                .iter()
                .find(|(am, _)| *am == m)
                .map(|(_, c)| *c)
                .unwrap_or(1)
        };
        let transient = BlockModule::ALL
            .into_iter()
            .map(|m| self.module_transient_elems(cfg, m, dap, chunks_of(m)))
            .fold(0.0, f64::max);
        self.elem_bytes * (self.resident_elems(cfg, dap) + transient)
            + self.fixed_overhead
    }

    /// Peak bytes of the naive fully-unchunked execution (every module's
    /// transient materialized whole) — the AutoChunk savings baseline.
    pub fn unchunked_peak_bytes(&self, cfg: &ModelConfig, dap: usize) -> f64 {
        self.planned_peak_bytes(cfg, dap, &[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::perfmodel::gpu::GpuSpec;

    #[test]
    fn paper_20gb_claim() {
        // §III.B: N_r=384, N_head=4, 48 layers, bf16 -> > 20 GB
        let cfg = ModelConfig::finetune();
        let m = MemoryModel { elem_bytes: BF16, ..MemoryModel::default() };
        let gb = m.attention_activation_all_blocks(&cfg) / 1e9;
        assert!(gb > 20.0 && gb < 25.0, "{gb} GB");
    }

    #[test]
    fn table5_oom_boundary() {
        // Single device + chunking OOMs by 3072; DAP-8 fits 4096 (Table V)
        let m = MemoryModel::default();
        let cap = GpuSpec::a100_40g().memory;
        let at = |n, dap, chunk| m.check(&ModelConfig::inference(n), dap, chunk, cap);
        assert!(at(2560, 1, 16).is_ok(), "2560 single+chunk should fit");
        assert!(at(3072, 1, 16).is_err(), "3072 single should OOM");
        assert!(at(4096, 8, 1).is_ok(), "4096 DAP-8 should fit");
        assert!(at(4096, 4, 1).is_err(), "4096 DAP-4 should OOM");
        assert!(at(3584, 4, 1).is_ok(), "3584 DAP-4 should fit");
    }

    #[test]
    fn dap_shards_memory() {
        let m = MemoryModel::default();
        let cfg = ModelConfig::inference(2048);
        let m1 = m.inference_peak(&cfg, 1, 1);
        let m4 = m.inference_peak(&cfg, 4, 1);
        assert!(m4 < m1 * 0.45, "m1={m1:e} m4={m4:e}");
    }

    #[test]
    fn chunking_cuts_transients_only() {
        let m = MemoryModel::default();
        let cfg = ModelConfig::inference(2048);
        let no = m.inference_peak(&cfg, 1, 1);
        let ch = m.inference_peak(&cfg, 1, 16);
        assert!(ch < no);
        // resident part persists: chunked is still a large fraction
        assert!(ch > 0.1 * no);
    }

    #[test]
    fn module_transients_monotone_in_chunks() {
        let m = MemoryModel::default();
        let cfg = ModelConfig::inference(2048);
        for module in BlockModule::ALL {
            let mut prev = f64::INFINITY;
            for c in [1usize, 2, 3, 5, 8, 64, 100_000] {
                let t = m.module_transient_elems(&cfg, module, 1, c);
                assert!(t > 0.0);
                assert!(t <= prev, "{} at c={c}", module.name());
                prev = t;
            }
        }
    }

    #[test]
    fn triangle_mult_is_not_chunkable() {
        let m = MemoryModel::default();
        let cfg = ModelConfig::inference(3072);
        let t1 = m.module_transient_elems(&cfg, BlockModule::TriangleMult, 1, 1);
        let t64 = m.module_transient_elems(&cfg, BlockModule::TriangleMult, 1, 64);
        assert_eq!(t1, t64);
        assert_eq!(BlockModule::TriangleMult.chunk_axis_len(&cfg, 1), 1);
        // matches the coarse model's irreducible term
        let r = cfg.n_res as f64;
        assert_eq!(t1, 5.0 * r * r * cfg.d_pair as f64);
    }

    #[test]
    fn triangle_attention_dominates_unchunked() {
        // §III.B: the h_p · r³ pair-attention scores are the biggest naive
        // transient at long sequence lengths
        let m = MemoryModel::default();
        let cfg = ModelConfig::inference(2048);
        let tri_attn =
            m.module_transient_elems(&cfg, BlockModule::TriangleAttnStart, 1, 1);
        for module in BlockModule::ALL {
            assert!(
                m.module_transient_elems(&cfg, module, 1, 1) <= tri_attn,
                "{}",
                module.name()
            );
        }
        let r = cfg.n_res as f64;
        assert_eq!(tri_attn, cfg.n_heads_pair as f64 * r * r * r);
    }

    #[test]
    fn module_names_roundtrip() {
        for module in BlockModule::ALL {
            assert_eq!(BlockModule::parse(module.name()).unwrap(), module);
        }
        assert!(BlockModule::parse("nope").is_err());
    }

    #[test]
    fn planned_peak_uses_worst_module() {
        let m = MemoryModel::default();
        let cfg = ModelConfig::inference(2048);
        let naive = m.unchunked_peak_bytes(&cfg, 1);
        // chunking only triangle attention leaves msa-row as the next peak
        let partial = m.planned_peak_bytes(
            &cfg,
            1,
            &[
                (BlockModule::TriangleAttnStart, 64),
                (BlockModule::TriangleAttnEnd, 64),
            ],
        );
        assert!(partial < naive);
        let row = m.module_transient_elems(&cfg, BlockModule::MsaRowAttn, 1, 1);
        let expect = m.elem_bytes * (m.resident_elems(&cfg, 1) + row)
            + m.fixed_overhead;
        assert!((partial - expect).abs() < 1.0, "{partial} vs {expect}");
    }
}
