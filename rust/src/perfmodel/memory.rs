//! Activation-memory model → the Table V OOM boundary.
//!
//! The paper's §III.B headline: attention context memory scales as
//! N_r³ · N_head · sizeof(bf16) in the pair stack (> 20 GB at N_r = 384
//! over 48 layers). We model the peak *inference* working set per device:
//! representations + the largest transient per block (attention scores or
//! triangle intermediates), under chunking (baselines) or DAP sharding
//! (FastFold), and declare sim-OOM when it exceeds device capacity.

use crate::config::ModelConfig;
use crate::error::{Error, Result};

pub const BF16: f64 = 2.0;
pub const F32: f64 = 4.0;

#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    /// bytes per element of activations
    pub elem_bytes: f64,
    /// framework/weights/workspace overhead per device (bytes)
    pub fixed_overhead: f64,
}

impl Default for MemoryModel {
    fn default() -> Self {
        // inference activations are f32 (OpenFold/AlphaFold default);
        // weights + framework context ≈ 2 GB
        MemoryModel { elem_bytes: F32, fixed_overhead: 2.0e9 }
    }
}

impl MemoryModel {
    /// Peak inference working set per device (bytes).
    ///
    /// * `dap` — DAP degree (activations sharded 1/dap; transient attention
    ///   batch is over the local shard).
    /// * `chunk` — chunking factor along the batch axis of attention
    ///   (baseline path; 1 = no chunking). Chunking shrinks transients but
    ///   NOT the resident representations — that is why the baselines still
    ///   OOM at 3k+ (paper Table V).
    pub fn inference_peak(&self, cfg: &ModelConfig, dap: usize, chunk: usize) -> f64 {
        let s = cfg.n_seq as f64;
        let r = cfg.n_res as f64;
        let dm = cfg.d_msa as f64;
        let dz = cfg.d_pair as f64;
        let hp = cfg.n_heads_pair as f64;
        let hm = cfg.n_heads_msa as f64;
        let dap = dap as f64;
        let chunk = chunk as f64;

        let _ = hp;
        // resident: m (+ residual copy) + z (2 working copies + the
        // recycling buffer AlphaFold keeps between recycle iterations)
        let resident = (2.0 * s * r * dm + 3.0 * r * r * dz) / dap;

        // largest transients per block:
        // attention scores for the processed batch slice (chunkable — the
        // chunking technique of §V.C targets exactly these):
        let msa_attn = (s / dap / chunk).max(1.0) * hm * r * r;
        // triangle-mult working set: left/right projections + gates + the
        // contraction output. NOT chunkable along the batch axis (the k
        // contraction needs the full axis) — this is what keeps the
        // baselines OOMing past ~3k residues even with chunking (Table V).
        let tri_mult = if dap > 1.0 {
            // local projections (4/dap) + gathered right operand (1) +
            // full incoming partial (1) + working copies (0.75)
            (4.0 / dap + 2.75) * r * r * dz
        } else {
            5.0 * r * r * dz
        };
        let transient = msa_attn.max(tri_mult);

        self.elem_bytes * (resident + transient) + self.fixed_overhead
    }

    /// The paper's §III.B training bound: storing row-attention context for
    /// backward across all blocks without checkpointing.
    pub fn attention_activation_all_blocks(&self, cfg: &ModelConfig) -> f64 {
        let r = cfg.n_res as f64;
        let h = cfg.n_heads_pair as f64;
        cfg.n_blocks as f64 * r * r * r * h * self.elem_bytes
    }

    /// Check an inference plan against device capacity.
    pub fn check(
        &self,
        cfg: &ModelConfig,
        dap: usize,
        chunk: usize,
        capacity: f64,
    ) -> Result<f64> {
        let need = self.inference_peak(cfg, dap, chunk);
        if need > capacity {
            Err(Error::SimOom { need_gib: need / 1e9, cap_gib: capacity / 1e9 })
        } else {
            Ok(need)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::perfmodel::gpu::GpuSpec;

    #[test]
    fn paper_20gb_claim() {
        // §III.B: N_r=384, N_head=4, 48 layers, bf16 -> > 20 GB
        let cfg = ModelConfig::finetune();
        let m = MemoryModel { elem_bytes: BF16, ..MemoryModel::default() };
        let gb = m.attention_activation_all_blocks(&cfg) / 1e9;
        assert!(gb > 20.0 && gb < 25.0, "{gb} GB");
    }

    #[test]
    fn table5_oom_boundary() {
        // Single device + chunking OOMs by 3072; DAP-8 fits 4096 (Table V)
        let m = MemoryModel::default();
        let cap = GpuSpec::a100_40g().memory;
        let at = |n, dap, chunk| m.check(&ModelConfig::inference(n), dap, chunk, cap);
        assert!(at(2560, 1, 16).is_ok(), "2560 single+chunk should fit");
        assert!(at(3072, 1, 16).is_err(), "3072 single should OOM");
        assert!(at(4096, 8, 1).is_ok(), "4096 DAP-8 should fit");
        assert!(at(4096, 4, 1).is_err(), "4096 DAP-4 should OOM");
        assert!(at(3584, 4, 1).is_ok(), "3584 DAP-4 should fit");
    }

    #[test]
    fn dap_shards_memory() {
        let m = MemoryModel::default();
        let cfg = ModelConfig::inference(2048);
        let m1 = m.inference_peak(&cfg, 1, 1);
        let m4 = m.inference_peak(&cfg, 4, 1);
        assert!(m4 < m1 * 0.45, "m1={m1:e} m4={m4:e}");
    }

    #[test]
    fn chunking_cuts_transients_only() {
        let m = MemoryModel::default();
        let cfg = ModelConfig::inference(2048);
        let no = m.inference_peak(&cfg, 1, 1);
        let ch = m.inference_peak(&cfg, 1, 16);
        assert!(ch < no);
        // resident part persists: chunked is still a large fraction
        assert!(ch > 0.1 * no);
    }
}
