//! Closed-form FLOP counts for every Evoformer module (forward), mirroring
//! model.py op-for-op. Backward is priced at the standard 2× forward.
//!
//! Conventions: a GEMM of (a×b)·(b×c) costs 2abc FLOPs; attention over
//! B batch rows, L keys, h heads, d head-dim costs 2·B·h·L²·d for QKᵀ and
//! the same for PV; LayerNorm/softmax/elementwise are counted at their
//! element counts (they matter for the *memory-bound* fraction the paper's
//! §III.B analysis highlights, not the FLOP total).

use super::memory::BlockModule;
use crate::config::ModelConfig;

#[derive(Clone, Copy, Debug, Default)]
pub struct BlockFlops {
    pub gemm: f64,
    pub attention: f64,
    pub triangle: f64,
    pub opm: f64,
    pub batch_reduce_elems: f64,
    pub elementwise_elems: f64,
}

impl BlockFlops {
    pub fn total(&self) -> f64 {
        self.gemm + self.attention + self.triangle + self.opm
    }
}

fn gemm(a: f64, b: f64, c: f64) -> f64 {
    2.0 * a * b * c
}

/// Forward FLOPs of one Evoformer block at (n_seq, n_res) = (s, r).
pub fn block_flops(cfg: &ModelConfig, s: usize, r: usize) -> BlockFlops {
    let (s, r) = (s as f64, r as f64);
    let dm = cfg.d_msa as f64;
    let dz = cfg.d_pair as f64;
    let hm = cfg.n_heads_msa as f64;
    let hp = cfg.n_heads_pair as f64;
    let dh = cfg.d_head as f64;
    let t = cfg.transition_factor as f64;
    let dopm = cfg.d_opm as f64;

    let mut f = BlockFlops::default();

    // --- MSA stack
    // row attention: qkvg merge-GEMM + out proj + bias proj
    f.gemm += gemm(s * r, dm, 4.0 * hm * dh); // qkvg
    f.gemm += gemm(s * r, hm * dh, dm); // out
    f.gemm += gemm(r * r, dz, hm); // pair bias proj
    f.attention += 2.0 * gemm(s * hm, r, r * dh / hm / hm).max(0.0); // placeholder, replaced below
    f.attention = 0.0;
    f.attention += 2.0 * 2.0 * s * hm * r * r * dh; // QK^T + PV, row attn
    // col attention
    f.gemm += gemm(s * r, dm, 4.0 * hm * dh);
    f.gemm += gemm(s * r, hm * dh, dm);
    f.attention += 2.0 * 2.0 * r * hm * s * s * dh;
    // msa transition
    f.gemm += gemm(s * r, dm, t * dm) + gemm(s * r, t * dm, dm);

    // --- communication
    // OPM: projections + outer product + out proj
    f.gemm += gemm(s * r, dm, 2.0 * dopm);
    f.opm += 2.0 * r * r * dopm * dopm * s; // einsum sid,sje->ijde
    f.gemm += gemm(r * r, dopm * dopm, dz);

    // --- pair stack
    // 2 × triangle mult: proj/gates + contraction + out
    for _ in 0..2 {
        f.gemm += gemm(r * r, dz, 4.0 * dz);
        f.triangle += 2.0 * r * r * r * dz; // ikc,jkc->ijc
        f.gemm += gemm(r * r, dz, dz) + gemm(r * r, dz, dz);
    }
    // 2 × triangle attention (start/end): qkvg + out + bias
    for _ in 0..2 {
        f.gemm += gemm(r * r, dz, 4.0 * hp * dh);
        f.gemm += gemm(r * r, hp * dh, dz);
        f.gemm += gemm(r * r, dz, hp);
        f.attention += 2.0 * 2.0 * r * hp * r * r * dh;
    }
    // pair transition
    f.gemm += gemm(r * r, dz, t * dz) + gemm(r * r, t * dz, dz);

    // memory-bound op volumes (element counts, for the §III.B breakdown):
    // 12 LayerNorms/block (paper §IV.A.3) + softmaxes
    f.batch_reduce_elems = 4.0 * s * r * dm + 8.0 * r * r * dz // LN passes
        + s * hm * r * r + r * hm * s * s + 2.0 * r * hp * r * r; // softmax rows
    f.elementwise_elems = 8.0 * s * r * dm + 16.0 * r * r * dz;

    f
}

/// Forward FLOPs of one Evoformer sub-module at the config's own
/// `(n_seq, n_res)` — the same terms [`block_flops`] sums, regrouped per
/// [`BlockModule`] so the AutoChunk planner can weight chunk overhead by a
/// module's runtime share. Invariant (tested below): the sum over
/// [`BlockModule::ALL`] equals `block_flops(cfg, n_seq, n_res).total()`.
pub fn module_flops(cfg: &ModelConfig, module: BlockModule) -> f64 {
    let s = cfg.n_seq as f64;
    let r = cfg.n_res as f64;
    let dm = cfg.d_msa as f64;
    let dz = cfg.d_pair as f64;
    let hm = cfg.n_heads_msa as f64;
    let hp = cfg.n_heads_pair as f64;
    let dh = cfg.d_head as f64;
    let t = cfg.transition_factor as f64;
    let dopm = cfg.d_opm as f64;
    match module {
        BlockModule::MsaRowAttn => {
            gemm(s * r, dm, 4.0 * hm * dh)
                + gemm(s * r, hm * dh, dm)
                + gemm(r * r, dz, hm)
                + 4.0 * s * hm * r * r * dh
        }
        BlockModule::MsaColAttn => {
            gemm(s * r, dm, 4.0 * hm * dh)
                + gemm(s * r, hm * dh, dm)
                + 4.0 * r * hm * s * s * dh
        }
        BlockModule::OuterProductMean => {
            gemm(s * r, dm, 2.0 * dopm)
                + 2.0 * r * r * dopm * dopm * s
                + gemm(r * r, dopm * dopm, dz)
        }
        BlockModule::MsaTransition => {
            gemm(s * r, dm, t * dm) + gemm(s * r, t * dm, dm)
        }
        BlockModule::TriangleMult => {
            2.0 * (gemm(r * r, dz, 4.0 * dz)
                + 2.0 * r * r * r * dz
                + 2.0 * gemm(r * r, dz, dz))
        }
        BlockModule::TriangleAttnStart | BlockModule::TriangleAttnEnd => {
            gemm(r * r, dz, 4.0 * hp * dh)
                + gemm(r * r, hp * dh, dz)
                + gemm(r * r, dz, hp)
                + 4.0 * r * hp * r * r * dh
        }
        BlockModule::PairTransition => {
            gemm(r * r, dz, t * dz) + gemm(r * r, t * dz, dz)
        }
    }
}

/// Whole-model forward FLOPs (embed/heads are negligible vs the trunk).
pub fn model_flops(cfg: &ModelConfig) -> f64 {
    cfg.n_blocks as f64 * block_flops(cfg, cfg.n_seq, cfg.n_res).total()
}

/// Training-step FLOPs: fwd + 2× bwd (standard estimate), with AlphaFold's
/// recycling multiplying the forward count (mean 2.5 recycles during
/// training: uniform 1..4, paper §II.A).
pub fn train_step_flops(cfg: &ModelConfig, recycles: f64) -> f64 {
    let fwd = model_flops(cfg);
    fwd * recycles + 3.0 * fwd // (recycles-1) fwd-only passes + 1 fwd+bwd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn cubic_in_r_for_pair_stack() {
        let cfg = ModelConfig::initial_training();
        let f1 = block_flops(&cfg, 128, 128);
        let f2 = block_flops(&cfg, 128, 256);
        // triangle term scales ~r^3
        let ratio = f2.triangle / f1.triangle;
        assert!((ratio - 8.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn gemm_fraction_small() {
        // paper §III.B: GEMM is a minority of runtime because batch-reduce
        // dominates; at least verify GEMM doesn't dwarf attention+triangle
        let cfg = ModelConfig::finetune();
        let f = block_flops(&cfg, cfg.n_seq, cfg.n_res);
        assert!(f.triangle + f.attention + f.opm > 0.2 * f.gemm);
    }

    #[test]
    fn finetune_flops_are_petaflop_scale() {
        // sanity: a finetune training step (batch 128) is O(10^16) FLOPs —
        // consistent with 6 PFLOPS × ~4 s step time (paper Table IV)
        let cfg = ModelConfig::finetune();
        let step = train_step_flops(&cfg, 2.5) * 128.0;
        assert!(step > 1e15 && step < 1e18, "step {step:e}");
    }

    #[test]
    fn positive_everything() {
        let cfg = ModelConfig::tiny();
        let f = block_flops(&cfg, cfg.n_seq, cfg.n_res);
        assert!(f.gemm > 0.0 && f.attention > 0.0 && f.triangle > 0.0);
        assert!(f.opm > 0.0 && f.batch_reduce_elems > 0.0);
    }

    #[test]
    fn module_flops_sum_to_block_total() {
        // the per-module regrouping must cover block_flops exactly
        for cfg in [
            ModelConfig::tiny(),
            ModelConfig::initial_training(),
            ModelConfig::inference(2048),
        ] {
            let total: f64 = BlockModule::ALL
                .into_iter()
                .map(|m| module_flops(&cfg, m))
                .sum();
            let want = block_flops(&cfg, cfg.n_seq, cfg.n_res).total();
            assert!(
                (total - want).abs() <= 1e-9 * want,
                "{}: {total:e} vs {want:e}",
                cfg.name
            );
        }
    }
}
