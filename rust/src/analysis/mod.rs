//! Static analysis over DAP/comm programs — the admission plane.
//!
//! Two planes live here:
//!
//! * **Schedule verification** ([`ir`], [`verifier`]): every schedule step
//!   is lifted into an effect IR and a per-rank abstract interpreter
//!   proves (or refutes, with structured diagnostics) the absence of the
//!   hazard classes the PR 2 runtime detectors catch mid-run — stale
//!   reads past an async trigger, write-after-write on in-flight landing
//!   slots, unknown/double waits, id reuse, unjoined collectives at
//!   schedule end — plus shard-shape soundness and backward liveness.
//!   The planner ([`crate::inference::engine::PlacementPlanner`]), the
//!   trainer ([`crate::train::ParallelPlan::admit_schedule`]) and the
//!   daemon request path all call [`admit`] before any rank executes;
//!   `fastfold verify` exposes the same pass on the CLI.
//! * **Determinism lint** ([`lint`]): a repo-source scan for banned
//!   nondeterminism patterns (unordered-container iteration feeding
//!   serialized output, wall-clock reads outside annotated measurement
//!   planes), surfaced as `fastfold lint` and run in CI.

pub mod ir;
pub mod lint;
pub mod verifier;

pub use ir::{canonical_entry, canonical_schedule, Program};
pub use verifier::{verify, verify_backward, Diagnostic, Hazard, VerifyReport};

use crate::config::ModelConfig;
use crate::error::Result;

/// Verify the canonical per-block DAP program (forward and backward) for
/// `cfg` at degree `n`, returning both reports without gating. Entry
/// shard shapes are used when `n` divides the preset's axial dims;
/// otherwise the analysis runs shape-agnostic (geometry divisibility
/// stays the coordinator's launch-time check, exactly as before).
pub fn verify_canonical(
    name: &str,
    cfg: &ModelConfig,
    n: usize,
) -> (VerifyReport, VerifyReport) {
    let schedule = ir::canonical_schedule();
    let entry = ir::canonical_entry(cfg, n)
        .unwrap_or_else(|_| vec![("m", None), ("z", None)]);
    let program = ir::Program::from_schedule(name, &schedule, n, &entry);
    let forward = verifier::verify(&program);
    let backward = verifier::verify_backward(name, &schedule, n);
    (forward, backward)
}

/// The mandatory admission gate: statically prove the canonical DAP
/// program hazard-free (forward + backward) at degree `n` before any
/// rank executes. Returns the verifier's own cost in microseconds on
/// success; refuses admission ([`crate::Error::Schedule`], carrying the
/// leading diagnostics) on any hazard. Degree ≤ 1 runs no DAP schedule
/// and admits for free. The `--unsafe-skip-verify` escape hatch is the
/// caller's: skip calling this at all.
pub fn admit(origin: &str, cfg: &ModelConfig, n: usize) -> Result<u128> {
    if n <= 1 {
        return Ok(0);
    }
    let name = format!("{origin}:{}", cfg.name);
    let (forward, backward) = verify_canonical(&name, cfg, n);
    forward.gate()?;
    backward.gate()?;
    Ok(forward.elapsed_micros + backward.elapsed_micros)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_accepts_all_shipping_geometries() {
        for preset in ["tiny", "small", "initial_training", "finetune"] {
            let cfg = ModelConfig::preset(preset).unwrap();
            for n in [1usize, 2, 4, 8] {
                admit("test", &cfg, n).unwrap_or_else(|e| {
                    panic!("{preset} at dap={n} must admit: {e}")
                });
            }
        }
    }

    #[test]
    fn admission_is_shape_agnostic_on_nondividing_geometry() {
        // dap=3 does not divide tiny's (8, 16): the gate still verifies
        // the hazard classes and admits — geometry divisibility stays
        // the coordinator's launch-time rejection, as before this gate.
        let cfg = ModelConfig::tiny();
        assert!(admit("test", &cfg, 3).is_ok());
    }

    #[test]
    fn degree_one_admits_for_free() {
        let cfg = ModelConfig::tiny();
        assert_eq!(admit("test", &cfg, 1).unwrap(), 0);
    }
}
