//! Source lint: a self-contained scan of the repo's Rust source for
//! banned patterns — nondeterminism on output paths, kernel calls that
//! bypass the device-backend dispatch plane, and panics inside the
//! fault-recovery planes.
//!
//! Four rules, mirroring the conventions the codebase is built on:
//!
//! * **unordered-container** — hash-keyed maps/sets (the two
//!   `std::collections` unordered containers) anywhere in the source.
//!   Every map that can feed serialized output (JSON ledgers,
//!   manifests, comm logs, reports) is a `BTreeMap`/`BTreeSet` in this
//!   repo so iteration order is part of the contract; an unordered
//!   container is one refactor away from a nondeterministic ledger.
//!   Per-line escape: a `lint:allow(unordered)` comment on the same line.
//! * **wallclock** — `Instant` / system-time reads outside an
//!   annotated measurement plane. Real-clock reads are legitimate only
//!   where wall time *is* the measurement (the `MeasuredComm` ledger,
//!   bench harnesses, the verifier's own cost line); those files carry a
//!   file-level `lint:allow(wallclock)` marker next to their
//!   `use std::time` import, with a justification. A wall-clock read in
//!   an unannotated file is flagged — that is how time leaks into
//!   schedules, seeds, and serialized output.
//! * **backend-bypass** — direct kernel-plane paths or raw mutable
//!   tensor-view math outside the device plane. All kernel dispatch
//!   goes through `crate::device` (`DeviceBackend`), so planner,
//!   engine, daemon, and trainer never name a concrete backend; a
//!   direct call silently pins the scalar path and dodges the
//!   simd/thread configuration. Only the *code* part of a line is
//!   matched (anything before the first `//` — rustdoc prose is
//!   exempt), and the escape marker `lint:allow(backend)` is honored on
//!   the flagged line or the line immediately above, for the sanctioned
//!   sites: the device plane itself, the oracle, and bench baselines.
//! * **panic-in-recovery** — `unwrap`/`expect`/`panic!` in the
//!   recovery planes (`faults/`, `train/checkpoint.rs`, the serve
//!   daemon): code that exists to absorb failure must not introduce its
//!   own aborts — a panic in a retry path turns an injected fault into
//!   a real crash. Scoped to non-test code (everything before the first
//!   `#[cfg(test)]`), matched on the code part of a line only, with a
//!   same-line `lint:allow(panic)` escape for invariant-guarded sites.
//!
//! The patterns below are assembled with `concat!` so this file never
//! matches its own rules.

use crate::error::{Error, Result};
use std::fmt;
use std::path::{Path, PathBuf};

/// Patterns whose presence on a line flags the unordered-container rule.
const UNORDERED: [&str; 2] = [concat!("Hash", "Map"), concat!("Hash", "Set")];
/// Patterns whose presence on a line flags the wallclock rule.
const WALLCLOCK: [&str; 2] =
    [concat!("Instant", "::now("), concat!("System", "Time")];
/// Patterns whose presence in the code part of a line (before any `//`)
/// flags the backend-bypass rule: kernel-plane paths and raw mutable
/// tensor views are only legal inside the device plane.
const BACKEND_BYPASS: [&str; 2] =
    [concat!("kernels", "::"), concat!(".data_mut", "(")];
/// Same-line escape marker for the unordered-container rule.
const ALLOW_UNORDERED: &str = concat!("lint:allow(", "unordered)");
/// File-level escape marker declaring an annotated measurement plane.
const ALLOW_WALLCLOCK: &str = concat!("lint:allow(", "wallclock)");
/// Escape marker for the backend-bypass rule, honored on the flagged
/// line or the line immediately above (so a justification comment can
/// sit over a `use` or call without widening the line).
const ALLOW_BACKEND: &str = concat!("lint:allow(", "backend)");
/// Patterns whose presence in the code part of a line flags the
/// panic-in-recovery rule inside the recovery planes.
const PANIC_PATTERNS: [&str; 3] =
    [concat!(".unwrap", "()"), concat!(".expect", "("), concat!("panic!", "(")];
/// Same-line escape marker for the panic-in-recovery rule.
const ALLOW_PANIC: &str = concat!("lint:allow(", "panic)");

/// Whether `name` is inside a recovery plane the panic rule covers.
fn panic_rule_scoped(name: &str) -> bool {
    let norm = name.replace('\\', "/");
    norm.contains("/faults/")
        || norm.ends_with("faults.rs")
        || norm.ends_with("train/checkpoint.rs")
        || norm.ends_with("engine/daemon.rs")
}

/// One banned-pattern hit: where, which rule, and the offending line.
#[derive(Clone, Debug)]
pub struct Violation {
    /// Path of the flagged file (as given to the scan).
    pub file: String,
    /// 1-indexed line number.
    pub line: usize,
    /// Rule name: `unordered-container`, `wallclock`, `backend-bypass`,
    /// or `panic-in-recovery`.
    pub rule: &'static str,
    /// The flagged source line, trimmed.
    pub excerpt: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.excerpt
        )
    }
}

/// Lint one file's source text. `name` is used in diagnostics.
pub fn lint_source(name: &str, src: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    // the file-level marker declares the whole file a measurement plane
    let wallclock_allowed = src.contains(ALLOW_WALLCLOCK);
    let lines: Vec<&str> = src.lines().collect();
    // the panic rule stops at the first test module: tests exercise
    // failures and unwrap freely
    let panic_scoped = panic_rule_scoped(name);
    let test_start = lines
        .iter()
        .position(|l| l.contains("#[cfg(test)]"))
        .unwrap_or(lines.len());
    for (i, &line) in lines.iter().enumerate() {
        if UNORDERED.iter().any(|p| line.contains(p))
            && !line.contains(ALLOW_UNORDERED)
        {
            out.push(Violation {
                file: name.to_string(),
                line: i + 1,
                rule: "unordered-container",
                excerpt: line.trim().to_string(),
            });
        }
        if !wallclock_allowed && WALLCLOCK.iter().any(|p| line.contains(p)) {
            out.push(Violation {
                file: name.to_string(),
                line: i + 1,
                rule: "wallclock",
                excerpt: line.trim().to_string(),
            });
        }
        // backend-bypass matches only code, not comment text: rustdoc
        // that *documents* the kernel plane must not trip the rule
        let code = line.split("//").next().unwrap_or("");
        let allowed = line.contains(ALLOW_BACKEND)
            || (i > 0 && lines[i - 1].contains(ALLOW_BACKEND));
        if BACKEND_BYPASS.iter().any(|p| code.contains(p)) && !allowed {
            out.push(Violation {
                file: name.to_string(),
                line: i + 1,
                rule: "backend-bypass",
                excerpt: line.trim().to_string(),
            });
        }
        if panic_scoped
            && i < test_start
            && PANIC_PATTERNS.iter().any(|p| code.contains(p))
            && !line.contains(ALLOW_PANIC)
        {
            out.push(Violation {
                file: name.to_string(),
                line: i + 1,
                rule: "panic-in-recovery",
                excerpt: line.trim().to_string(),
            });
        }
    }
    out
}

/// Recursively lint every `.rs` file under `root`, in sorted path order
/// (the report itself must be deterministic).
pub fn lint_dir(root: &Path) -> Result<Vec<Violation>> {
    if !root.is_dir() {
        return Err(Error::Config(format!(
            "lint: '{}' is not a directory",
            root.display()
        )));
    }
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in files {
        let src = std::fs::read_to_string(&path)?;
        out.extend(lint_source(&path.display().to_string(), &src));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unordered_containers_are_flagged_with_line_escape() {
        let bad = format!("use std::collections::{};\n", UNORDERED[0]);
        let v = lint_source("x.rs", &bad);
        assert_eq!(v.len(), 1);
        assert_eq!((v[0].rule, v[0].line), ("unordered-container", 1));

        let ok = format!(
            "use std::collections::{}; // {} — counts only, never iterated\n",
            UNORDERED[1],
            ALLOW_UNORDERED
        );
        assert!(lint_source("x.rs", &ok).is_empty());
    }

    #[test]
    fn wallclock_needs_a_file_level_marker() {
        let pat = WALLCLOCK[0];
        let bad = format!("let t0 = {});\n", pat);
        let v = lint_source("x.rs", &bad);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "wallclock");

        let ok = format!(
            "use std::time::Instant; // {} — bench plane\nlet t0 = {});\n",
            ALLOW_WALLCLOCK, pat
        );
        assert!(lint_source("x.rs", &ok).is_empty());
    }

    #[test]
    fn backend_bypass_flags_code_but_not_docs() {
        let pat = BACKEND_BYPASS[0];
        let bad = format!("use crate::{}softmax;\n", pat);
        let v = lint_source("x.rs", &bad);
        assert_eq!(v.len(), 1);
        assert_eq!((v[0].rule, v[0].line), ("backend-bypass", 1));
        // rustdoc prose documenting the kernel plane is exempt
        let doc = format!("/// see {}softmax for the scalar path\n", pat);
        assert!(lint_source("x.rs", &doc).is_empty());
        // raw mutable tensor views are the other half of the rule
        let bad2 = format!("let d = t{});\n", BACKEND_BYPASS[1]);
        assert_eq!(lint_source("x.rs", &bad2).len(), 1);
    }

    #[test]
    fn backend_bypass_marker_same_line_or_line_above() {
        let pat = BACKEND_BYPASS[0];
        let same =
            format!("use crate::{}softmax; // {} — oracle\n", pat, ALLOW_BACKEND);
        assert!(lint_source("x.rs", &same).is_empty());
        let above =
            format!("// {} — oracle\nuse crate::{}softmax;\n", ALLOW_BACKEND, pat);
        assert!(lint_source("x.rs", &above).is_empty());
        // the marker must not leak further than one line down
        let far = format!("// {}\n\nuse crate::{}softmax;\n", ALLOW_BACKEND, pat);
        assert_eq!(lint_source("x.rs", &far).len(), 1);
    }

    #[test]
    fn panic_rule_is_scoped_to_recovery_planes() {
        let pat = PANIC_PATTERNS[0];
        let bad = format!("let v = x{};\n", pat);
        // inside a recovery plane: flagged
        let v = lint_source("rust/src/faults/mod.rs", &bad);
        assert_eq!(v.len(), 1);
        assert_eq!((v[0].rule, v[0].line), ("panic-in-recovery", 1));
        assert_eq!(
            lint_source("rust/src/train/checkpoint.rs", &bad).len(),
            1
        );
        assert_eq!(
            lint_source("rust/src/inference/engine/daemon.rs", &bad).len(),
            1
        );
        // outside the scoped planes: not this rule's business
        assert!(lint_source("rust/src/train/trainer.rs", &bad).is_empty());
    }

    #[test]
    fn panic_rule_escape_and_test_module_exemption() {
        let pat = PANIC_PATTERNS[1];
        // invariant-guarded sites escape with a same-line marker
        let ok = format!(
            "let v = x{}\"non-empty\"); // {} — guarded above\n",
            pat, ALLOW_PANIC
        );
        assert!(lint_source("rust/src/faults/mod.rs", &ok).is_empty());
        // everything after the first #[cfg(test)] is exempt: tests
        // exercise failure paths and unwrap freely
        let test_only = format!(
            "fn run() {{}}\n#[cfg(test)]\nmod tests {{\n    fn t() {{ x{}; }}\n}}\n",
            PANIC_PATTERNS[0]
        );
        assert!(lint_source("rust/src/faults/mod.rs", &test_only).is_empty());
    }

    #[test]
    fn clean_source_passes() {
        assert!(lint_source(
            "x.rs",
            "use std::collections::BTreeMap;\nfn main() {}\n"
        )
        .is_empty());
    }

    #[test]
    fn repo_source_tree_is_lint_clean() {
        // the satellite guarantee: the shipped tree has zero violations
        // (every legitimate wall-clock site carries its marker)
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let violations = lint_dir(&src).unwrap();
        assert!(
            violations.is_empty(),
            "lint violations in src/:\n{}",
            violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    #[test]
    fn missing_dir_is_a_config_error() {
        assert!(lint_dir(Path::new("/no/such/dir/fastfold")).is_err());
    }
}
