//! Per-rank abstract interpretation over the lifted schedule IR.
//!
//! The interpreter replays each rank's view of a [`Program`] against an
//! abstract state — which slots are defined (and at what shard shape),
//! which async collectives are in flight, which have been joined — and
//! mirrors the runtime detector order in `dap::executor` exactly: reads
//! are checked stale-then-unset, writes are checked against in-flight
//! landings, triggers check the landing slot before the id, waits are
//! authoritative about the pending set. Anything the PR 2 runtime
//! detectors would trip on mid-run is refuted here before any rank
//! executes; schedules the runtime would accept are accepted (the fuzz
//! suite in `rust/tests/schedule_verifier.rs` property-tests that
//! equivalence against the live executor).
//!
//! One deliberate asymmetry, shared with the runtime: async collectives
//! *snapshot* their input at the trigger (the executor clones shards into
//! the comm job), so overwriting an in-flight collective's input slot is
//! legal and is not flagged — only its *destination* slot is protected.
//!
//! Backward programs are checked by [`verify_backward`]: the forward
//! schedule is lowered to its tape (trigger-order, waits elided — the
//! same lowering `dap::tape` performs), versions are assigned with the
//! identical algorithm, and a reverse liveness walk proves every VJP
//! finds its cotangent and both `d_m` and `d_z` reach version 0. The walk
//! presumes the forward program verified hazard-free — tape-order
//! versioning only matches runtime write timing when no step reads a slot
//! between an async trigger landing there and its wait.

use super::ir::{CommKind, Program, Step};
use crate::error::{Error, Result};
use crate::json::Json;
use crate::manifest::ScheduleOp;
use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant; // lint:allow(wallclock) — verifier self-cost only

/// The hazard taxonomy: everything the static pass can refute.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Hazard {
    /// A step reads a slot that an in-flight async collective will
    /// overwrite — the read observes stale shards.
    StaleRead,
    /// A step writes a slot that an in-flight async collective will
    /// overwrite — the later join would clobber the newer value.
    WriteAfterWrite,
    /// `Wait` on an id that was never triggered (or was mistyped).
    UnknownWait,
    /// `Wait` on an id that was already joined earlier.
    DoubleWait,
    /// An async collective id re-triggered while still in flight.
    IdReuse,
    /// Async collectives still in flight when the schedule ends — the
    /// `Timeline::elapsed` class of bug, and leaked comm jobs.
    UnjoinedAtEnd,
    /// A step reads a slot nothing has defined.
    UnsetSlot,
    /// A collective whose shard geometry cannot execute (axis out of
    /// bounds, split dim not divisible by the dap degree).
    ShardShape,
    /// The backward pass cannot produce a required cotangent (seed slot
    /// never written, `d_m`/`d_z` unreachable, or an empty tape).
    BackwardLiveness,
}

impl Hazard {
    /// Stable kebab-case name used in reports and JSON artifacts.
    pub fn name(&self) -> &'static str {
        match self {
            Hazard::StaleRead => "stale-read",
            Hazard::WriteAfterWrite => "write-after-write",
            Hazard::UnknownWait => "unknown-wait",
            Hazard::DoubleWait => "double-wait",
            Hazard::IdReuse => "id-reuse",
            Hazard::UnjoinedAtEnd => "unjoined-at-end",
            Hazard::UnsetSlot => "unset-slot",
            Hazard::ShardShape => "shard-shape",
            Hazard::BackwardLiveness => "backward-liveness",
        }
    }
}

/// One refutation: where, who, what, and how to fix it.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Schedule step index the hazard manifests at.
    pub step: usize,
    /// First rank the hazard was observed on (schedules are SPMD, so
    /// hazards identical across ranks are reported once).
    pub rank: usize,
    /// Hazard class.
    pub hazard: Hazard,
    /// Buffer slot or collective id at the center of the hazard.
    pub buffer: String,
    /// Human-readable account of what goes wrong.
    pub detail: String,
    /// Suggested schedule edit that removes the hazard.
    pub fix: String,
}

impl Diagnostic {
    fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("step".to_string(), Json::Num(self.step as f64));
        obj.insert("rank".to_string(), Json::Num(self.rank as f64));
        obj.insert("hazard".to_string(), Json::Str(self.hazard.name().to_string()));
        obj.insert("buffer".to_string(), Json::Str(self.buffer.clone()));
        obj.insert("detail".to_string(), Json::Str(self.detail.clone()));
        obj.insert("fix".to_string(), Json::Str(self.fix.clone()));
        Json::Obj(obj)
    }
}

/// Verdict for one program: hazard-free, or a list of refutations.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    /// Program display name.
    pub program: String,
    /// DAP degree verified at.
    pub n: usize,
    /// Number of schedule steps analyzed.
    pub steps: usize,
    /// Refutations, in schedule order (empty = proven hazard-free).
    pub diagnostics: Vec<Diagnostic>,
    /// Wall-clock cost of the verification itself, in microseconds.
    pub elapsed_micros: u128,
}

impl VerifyReport {
    /// True when the abstract interpretation found no hazards.
    pub fn is_hazard_free(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Turn the report into a hard admission verdict: `Err` carrying the
    /// leading diagnostics when any hazard was refuted.
    pub fn gate(&self) -> Result<()> {
        if self.is_hazard_free() {
            return Ok(());
        }
        let mut lines: Vec<String> = self
            .diagnostics
            .iter()
            .take(4)
            .map(|d| {
                format!(
                    "[step {} {}] {} — fix: {}",
                    d.step,
                    d.hazard.name(),
                    d.detail,
                    d.fix
                )
            })
            .collect();
        if self.diagnostics.len() > lines.len() {
            lines.push(format!(
                "... and {} more (run `fastfold verify` for the full report)",
                self.diagnostics.len() - lines.len()
            ));
        }
        Err(Error::Schedule(format!(
            "schedule '{}' refused admission at dap={}: {} hazard(s): {}",
            self.program,
            self.n,
            self.diagnostics.len(),
            lines.join("; ")
        )))
    }

    /// Structured report for `fastfold verify --json` and CI artifacts.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("program".to_string(), Json::Str(self.program.clone()));
        obj.insert("dap".to_string(), Json::Num(self.n as f64));
        obj.insert("steps".to_string(), Json::Num(self.steps as f64));
        obj.insert("hazard_free".to_string(), Json::Bool(self.is_hazard_free()));
        obj.insert(
            "verify_micros".to_string(),
            Json::Num(self.elapsed_micros as f64),
        );
        obj.insert(
            "diagnostics".to_string(),
            Json::Arr(self.diagnostics.iter().map(|d| d.to_json()).collect()),
        );
        Json::Obj(obj)
    }
}

struct Inflight {
    dest: String,
    shape: Option<Vec<usize>>,
    trigger_step: usize,
}

/// Statically verify a forward program: per-rank abstract interpretation
/// proving the absence of every runtime-detector hazard class plus shard
/// geometry soundness.
pub fn verify(program: &Program) -> VerifyReport {
    let start = Instant::now();
    let mut diagnostics: Vec<Diagnostic> = Vec::new();
    let mut seen: BTreeSet<(usize, Hazard, String)> = BTreeSet::new();
    for rank in 0..program.n {
        for d in interpret_rank(program, rank) {
            if seen.insert((d.step, d.hazard, d.buffer.clone())) {
                diagnostics.push(d);
            }
        }
    }
    diagnostics.sort_by_key(|d| (d.step, d.hazard, d.buffer.clone()));
    VerifyReport {
        program: program.name.clone(),
        n: program.n,
        steps: program.steps.len(),
        diagnostics,
        elapsed_micros: start.elapsed().as_micros(),
    }
}

fn interpret_rank(program: &Program, rank: usize) -> Vec<Diagnostic> {
    let n = program.n;
    let mut out: Vec<Diagnostic> = Vec::new();
    // abstract state: slot -> shard shape where statically known
    let mut defined: BTreeMap<String, Option<Vec<usize>>> = program.entry.clone();
    let mut inflight: BTreeMap<String, Inflight> = BTreeMap::new();
    let mut joined: BTreeMap<String, usize> = BTreeMap::new(); // id -> join step

    for step in &program.steps {
        // 1. reads: stale-read first, then unset — the runtime order.
        for slot in &step.reads {
            if let Some((id, info)) =
                inflight.iter().find(|(_, v)| &v.dest == slot)
            {
                out.push(Diagnostic {
                    step: step.index,
                    rank,
                    hazard: Hazard::StaleRead,
                    buffer: slot.clone(),
                    detail: format!(
                        "{} reads slot '{slot}' while async collective '{id}' \
                         (triggered at step {}) has an in-flight write to it — \
                         the read observes stale shards",
                        step.label, info.trigger_step
                    ),
                    fix: format!("insert `wait '{id}'` before step {}", step.index),
                });
            }
            if !defined.contains_key(slot) {
                out.push(Diagnostic {
                    step: step.index,
                    rank,
                    hazard: Hazard::UnsetSlot,
                    buffer: slot.clone(),
                    detail: format!("{} reads slot '{slot}' which nothing has written", step.label),
                    fix: format!(
                        "add a step writing '{slot}' before step {}, or declare it a block entry",
                        step.index
                    ),
                });
                // recover: treat as defined with unknown shape so one
                // missing slot doesn't cascade into noise
                defined.insert(slot.clone(), None);
            }
        }

        // 2. collective shape transfer on the (single) read shard.
        let mut comm_shape: Option<Vec<usize>> = None;
        if let Some(kind) = &step.comm {
            let input_shape = step
                .reads
                .first()
                .and_then(|s| defined.get(s).cloned().flatten());
            if let Some(shape) = input_shape {
                match kind.transfer(&shape, n) {
                    Ok(s) => comm_shape = Some(s),
                    Err(why) => out.push(Diagnostic {
                        step: step.index,
                        rank,
                        hazard: Hazard::ShardShape,
                        buffer: step.reads.first().cloned().unwrap_or_default(),
                        detail: format!("{}: {}", step.label, why),
                        fix: "adjust the collective axes or the dap degree so shard \
                              dims divide evenly"
                            .to_string(),
                    }),
                }
            }
        }

        // 3. synchronous writes: write-after-write against in-flight
        //    landings, then define.
        for (wi, slot) in step.writes.iter().enumerate() {
            if let Some((id, info)) =
                inflight.iter().find(|(_, v)| &v.dest == slot)
            {
                out.push(Diagnostic {
                    step: step.index,
                    rank,
                    hazard: Hazard::WriteAfterWrite,
                    buffer: slot.clone(),
                    detail: format!(
                        "{} writes slot '{slot}' while async collective '{id}' \
                         (triggered at step {}) has an in-flight write to it — \
                         joining '{id}' would clobber the newer value",
                        step.label, info.trigger_step
                    ),
                    fix: format!("insert `wait '{id}'` before step {}", step.index),
                });
            }
            let shape = if step.comm.is_some() {
                comm_shape.clone()
            } else {
                step.seg
                    .as_ref()
                    .and_then(|seg| program.exec_shapes.get(seg))
                    .and_then(|shapes| shapes.get(wi).cloned())
            };
            defined.insert(slot.clone(), shape);
        }

        // 4. trigger: landing-slot WAW first, then id reuse — the order
        //    the runtime's `land()` checks in.
        if let Some(t) = &step.trigger {
            if let Some((id, info)) =
                inflight.iter().find(|(_, v)| v.dest == t.dest)
            {
                // triggering with dest == the in-flight id's own dest is
                // exactly the runtime WAW at land(); dest == own input is
                // legal (snapshot semantics) and never reaches here
                // because triggers don't write at issue time.
                out.push(Diagnostic {
                    step: step.index,
                    rank,
                    hazard: Hazard::WriteAfterWrite,
                    buffer: t.dest.clone(),
                    detail: format!(
                        "{} will land in slot '{}' while async collective '{id}' \
                         (triggered at step {}) is already in flight to it",
                        step.label, t.dest, info.trigger_step
                    ),
                    fix: format!("insert `wait '{id}'` before step {}", step.index),
                });
            }
            if inflight.contains_key(&t.id) {
                out.push(Diagnostic {
                    step: step.index,
                    rank,
                    hazard: Hazard::IdReuse,
                    buffer: t.id.clone(),
                    detail: format!(
                        "{} reuses async collective id '{}' while it is still in flight",
                        step.label, t.id
                    ),
                    fix: format!(
                        "insert `wait '{}'` before step {}, or use a distinct id",
                        t.id, step.index
                    ),
                });
            }
            // re-triggering an id after it was joined is legal; the id
            // simply becomes waitable again
            joined.remove(&t.id);
            inflight.insert(
                t.id.clone(),
                Inflight {
                    dest: t.dest.clone(),
                    shape: comm_shape.clone(),
                    trigger_step: step.index,
                },
            );
        }

        // 5. join: the landing write happens here.
        if let Some(id) = &step.join {
            match inflight.remove(id) {
                Some(info) => {
                    joined.insert(id.clone(), step.index);
                    defined.insert(info.dest, info.shape);
                }
                None => {
                    let (hazard, detail, fix) = match joined.get(id) {
                        Some(j) => (
                            Hazard::DoubleWait,
                            format!(
                                "wait on async collective id '{id}' which was already \
                                 joined at step {j}"
                            ),
                            format!("delete the duplicate wait at step {}", step.index),
                        ),
                        None => (
                            Hazard::UnknownWait,
                            format!(
                                "wait on async collective id '{id}' that was never \
                                 triggered (typo, or the trigger was removed)"
                            ),
                            format!(
                                "trigger a collective with id '{id}' before step {}, \
                                 or delete the wait",
                                step.index
                            ),
                        ),
                    };
                    out.push(Diagnostic {
                        step: step.index,
                        rank,
                        hazard,
                        buffer: id.clone(),
                        detail,
                        fix,
                    });
                }
            }
        }
    }

    // 6. schedule end: every collective must have been joined.
    let last = program.steps.len().saturating_sub(1);
    for (id, info) in &inflight {
        out.push(Diagnostic {
            step: info.trigger_step,
            rank,
            hazard: Hazard::UnjoinedAtEnd,
            buffer: id.clone(),
            detail: format!(
                "async collective '{id}' (triggered at step {}, landing in '{}') \
                 is still in flight when the schedule ends",
                info.trigger_step, info.dest
            ),
            fix: format!("append `wait '{id}'` at or before step {last}"),
        });
    }
    out
}

type Key = (String, usize);

/// Statically verify the backward program derived from `schedule`: lower
/// to the tape (trigger order, waits elided), assign versions with the
/// same algorithm as `dap::tape::assign_versions`, and prove by reverse
/// liveness that `run_backward` would produce both `d_m` and `d_z`.
/// Presumes the forward program already verified hazard-free.
pub fn verify_backward(name: &str, schedule: &[ScheduleOp], n: usize) -> VerifyReport {
    let start = Instant::now();
    let mut diagnostics: Vec<Diagnostic> = Vec::new();

    // Tape lowering: ops are recorded at trigger time, waits are not
    // recorded — filtering waits from schedule order reproduces it.
    struct TapeOp {
        label: String,
        reads: Vec<String>,
        writes: Vec<String>,
        is_exec: bool,
    }
    let mut tape: Vec<TapeOp> = Vec::new();
    for op in schedule {
        match op {
            ScheduleOp::Exec { seg, inputs, outputs } => tape.push(TapeOp {
                label: format!("segment '{seg}'"),
                reads: inputs.clone(),
                writes: outputs.clone(),
                is_exec: true,
            }),
            ScheduleOp::Gather { input, output, .. }
            | ScheduleOp::Scatter { input, output, .. }
            | ScheduleOp::AllToAll { input, output, .. } => tape.push(TapeOp {
                label: format!(
                    "{} -> '{output}'",
                    comm_kind_name(op)
                ),
                reads: vec![input.clone()],
                writes: vec![output.clone()],
                is_exec: false,
            }),
            ScheduleOp::Wait { .. } => {}
        }
    }

    if !tape.iter().any(|op| op.is_exec) {
        diagnostics.push(Diagnostic {
            step: 0,
            rank: 0,
            hazard: Hazard::BackwardLiveness,
            buffer: String::new(),
            detail: "empty tape: the schedule records no segment executions, so \
                     run_backward has nothing to differentiate"
                .to_string(),
            fix: "add at least one exec step, or skip backward for this schedule"
                .to_string(),
        });
    }

    // Version assignment — the dap::tape::assign_versions algorithm:
    // reads see the current version, writes bump it.
    let mut cur: BTreeMap<String, usize> = BTreeMap::new();
    let mut versioned: Vec<(Vec<Key>, Vec<Key>)> = Vec::new();
    for op in &tape {
        let in_keys: Vec<Key> = op
            .reads
            .iter()
            .map(|s| (s.clone(), *cur.get(s).unwrap_or(&0)))
            .collect();
        let out_keys: Vec<Key> = op
            .writes
            .iter()
            .map(|s| {
                let v = cur.get(s).copied().unwrap_or(0) + 1;
                cur.insert(s.clone(), v);
                (s.clone(), v)
            })
            .collect();
        versioned.push((in_keys, out_keys));
    }

    // Seeds: run_backward starts cotangents at the final versions of the
    // block outputs — a slot the tape never wrote cannot be seeded.
    let mut live: BTreeSet<Key> = BTreeSet::new();
    for slot in ["m", "z"] {
        match cur.get(slot) {
            Some(&v) => {
                live.insert((slot.to_string(), v));
            }
            None => diagnostics.push(Diagnostic {
                step: schedule.len().saturating_sub(1),
                rank: 0,
                hazard: Hazard::BackwardLiveness,
                buffer: slot.to_string(),
                detail: format!(
                    "tape never wrote '{slot}', so the backward seed d_{slot} has \
                     no version to attach to"
                ),
                fix: format!("the block must write '{slot}' at least once"),
            }),
        }
    }

    // Reverse liveness walk. Exec VJPs always run (missing cotangents
    // become zeros) and produce cotangents for every input; comm adjoints
    // run only when their output cotangent is live.
    for (op, (in_keys, out_keys)) in tape.iter().zip(versioned.iter()).rev() {
        if op.is_exec {
            for k in out_keys {
                live.remove(k);
            }
            for k in in_keys {
                live.insert(k.clone());
            }
        } else {
            let out_live = out_keys.iter().any(|k| live.contains(k));
            if out_live {
                for k in out_keys {
                    live.remove(k);
                }
                for k in in_keys {
                    live.insert(k.clone());
                }
            } else {
                // the adjoint collective is skipped: nothing downstream
                // consumed its output. Benign for pure comm plumbing,
                // but if its input cotangent is never produced by
                // another path, the entry liveness check below fires.
                let _ = &op.label;
            }
        }
    }

    for slot in ["m", "z"] {
        if cur.contains_key(slot) && !live.contains(&(slot.to_string(), 0)) {
            diagnostics.push(Diagnostic {
                step: 0,
                rank: 0,
                hazard: Hazard::BackwardLiveness,
                buffer: slot.to_string(),
                detail: format!(
                    "no cotangent path reaches '{slot}' at entry (version 0): \
                     run_backward would error `backward produced no d_{slot}`"
                ),
                fix: format!(
                    "ensure the dataflow from the entry '{slot}' to the block \
                     outputs is connected through differentiable steps"
                ),
            });
        }
    }

    VerifyReport {
        program: format!("{name}/backward"),
        n: n.max(1),
        steps: tape.len(),
        diagnostics,
        elapsed_micros: start.elapsed().as_micros(),
    }
}

fn comm_kind_name(op: &ScheduleOp) -> &'static str {
    match op {
        ScheduleOp::Gather { .. } => "gather",
        ScheduleOp::Scatter { .. } => "scatter",
        ScheduleOp::AllToAll { .. } => "all_to_all",
        _ => "",
    }
}

#[cfg(test)]
mod tests {
    use super::super::ir::{canonical_schedule, Program};
    use super::*;

    fn entry() -> Vec<(&'static str, Option<Vec<usize>>)> {
        vec![("m", None), ("z", None)]
    }

    fn verify_ops(ops: &[ScheduleOp], n: usize) -> VerifyReport {
        verify(&Program::from_schedule("test", ops, n, &entry()))
    }

    fn exec(seg: &str, inputs: &[&str], outputs: &[&str]) -> ScheduleOp {
        ScheduleOp::Exec {
            seg: seg.into(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
        }
    }

    fn gather(input: &str, output: &str, id: &str) -> ScheduleOp {
        ScheduleOp::Gather {
            input: input.into(),
            output: output.into(),
            axis: 0,
            id: Some(id.into()),
        }
    }

    fn wait(id: &str) -> ScheduleOp {
        ScheduleOp::Wait { id: id.into() }
    }

    #[test]
    fn canonical_forward_is_hazard_free() {
        for n in [1, 2, 4, 8] {
            let p = Program::from_schedule("canonical", &canonical_schedule(), n, &entry());
            let report = verify(&p);
            assert!(
                report.is_hazard_free(),
                "dap={n}: {:?}",
                report.diagnostics
            );
        }
    }

    #[test]
    fn canonical_backward_is_live() {
        for n in [1, 2, 4, 8] {
            let report = verify_backward("canonical", &canonical_schedule(), n);
            assert!(
                report.is_hazard_free(),
                "dap={n}: {:?}",
                report.diagnostics
            );
        }
    }

    #[test]
    fn stale_read_is_refuted() {
        // PR 2's stale-read shape: read the landing slot before the wait
        let ops = vec![
            gather("m", "g", "ag"),
            exec("use", &["g"], &["out"]),
            wait("ag"),
        ];
        let report = verify_ops(&ops, 2);
        let d = &report.diagnostics[0];
        assert_eq!(d.hazard, Hazard::StaleRead);
        assert_eq!(d.step, 1);
        assert_eq!(d.buffer, "g");
        assert!(d.fix.contains("wait 'ag'"), "{}", d.fix);
        assert!(report.gate().is_err());
    }

    #[test]
    fn waw_on_landing_slot_is_refuted() {
        let ops = vec![
            gather("m", "g", "ag"),
            exec("clobber", &["m"], &["g"]),
            wait("ag"),
        ];
        let report = verify_ops(&ops, 2);
        assert_eq!(report.diagnostics[0].hazard, Hazard::WriteAfterWrite);
        assert_eq!(report.diagnostics[0].buffer, "g");
    }

    #[test]
    fn input_overwrite_after_trigger_is_legal() {
        // snapshot semantics: the collective read 'm' at the trigger
        let ops = vec![
            gather("m", "g", "ag"),
            exec("bump", &["m"], &["m"]),
            wait("ag"),
        ];
        assert!(verify_ops(&ops, 2).is_hazard_free());
    }

    #[test]
    fn unknown_and_double_wait_are_distinguished() {
        let report = verify_ops(&[wait("nope")], 2);
        assert_eq!(report.diagnostics[0].hazard, Hazard::UnknownWait);

        let ops = vec![gather("m", "g", "ag"), wait("ag"), wait("ag")];
        let report = verify_ops(&ops, 2);
        assert_eq!(report.diagnostics.len(), 1);
        assert_eq!(report.diagnostics[0].hazard, Hazard::DoubleWait);
        assert_eq!(report.diagnostics[0].step, 2);
    }

    #[test]
    fn inflight_id_reuse_is_refuted_and_rearm_is_legal() {
        let ops = vec![gather("m", "g", "ag"), gather("z", "h", "ag"), wait("ag")];
        let report = verify_ops(&ops, 2);
        assert!(report.diagnostics.iter().any(|d| d.hazard == Hazard::IdReuse));

        // trigger -> wait -> trigger -> wait with the same id is legal
        let ops = vec![
            gather("m", "g", "ag"),
            wait("ag"),
            gather("z", "h", "ag"),
            wait("ag"),
        ];
        assert!(verify_ops(&ops, 2).is_hazard_free());
    }

    #[test]
    fn unjoined_at_end_is_refuted() {
        let report = verify_ops(&[gather("m", "g", "ag")], 2);
        assert_eq!(report.diagnostics[0].hazard, Hazard::UnjoinedAtEnd);
        assert_eq!(report.diagnostics[0].buffer, "ag");
    }

    #[test]
    fn unset_slot_is_refuted_once() {
        let ops = vec![exec("a", &["ghost"], &["x"]), exec("b", &["ghost"], &["y"])];
        let report = verify_ops(&ops, 2);
        // recovery defines the slot: one diagnostic, not a cascade
        assert_eq!(report.diagnostics.len(), 1);
        assert_eq!(report.diagnostics[0].hazard, Hazard::UnsetSlot);
    }

    #[test]
    fn shard_shape_divisibility_is_checked() {
        let p = Program::from_schedule(
            "shape",
            &[ScheduleOp::Scatter {
                input: "m".into(),
                output: "s".into(),
                axis: 0,
                id: None,
            }],
            4,
            &[("m", Some(vec![6, 8]))], // 6 % 4 != 0
        );
        let report = verify(&p);
        assert_eq!(report.diagnostics[0].hazard, Hazard::ShardShape);
    }

    #[test]
    fn backward_refutes_disconnected_entry() {
        // z is never part of the dataflow: d_z at version 0 unreachable
        let ops = vec![exec("only_m", &["m"], &["m"]), exec("z_new", &[], &["z"])];
        let report = verify_backward("disconnected", &ops, 2);
        assert!(report
            .diagnostics
            .iter()
            .any(|d| d.hazard == Hazard::BackwardLiveness && d.buffer == "z"));
    }

    #[test]
    fn backward_refutes_empty_tape() {
        let report = verify_backward("empty", &[wait("x")], 2);
        assert!(report
            .diagnostics
            .iter()
            .any(|d| d.hazard == Hazard::BackwardLiveness));
    }

    #[test]
    fn report_json_shape() {
        let report = verify_ops(&[gather("m", "g", "ag")], 2);
        let doc = report.to_json().to_string();
        assert!(doc.contains("\"hazard_free\": false") || doc.contains("\"hazard_free\":false"));
        assert!(doc.contains("unjoined-at-end"));
    }
}
