//! The verifier's intermediate representation: each [`ScheduleOp`] is
//! lifted into a [`Step`] that names only its *effects* — which slots it
//! reads at issue time, which it synchronously overwrites, which async
//! collective it triggers or joins, and the collective geometry needed for
//! shard-shape checks. The abstract interpreter ([`super::verifier`])
//! never looks at tensors; everything it proves, it proves from this IR.
//!
//! The canonical per-block DAP program (`python/compile/dap.py`'s
//! `SCHEDULE`, exported verbatim into `manifest.json`) is transcribed
//! here as [`canonical_schedule`] so admission gates and `fastfold
//! verify` can analyze it without artifacts on disk.

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::manifest::ScheduleOp;
use std::collections::BTreeMap;

/// Geometry of a collective, for shard-shape divisibility checks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommKind {
    /// `all_gather` along `axis`: shard dim grows ×n.
    Gather {
        /// concatenation axis
        axis: usize,
    },
    /// `reduce_scatter` along `axis`: shard dim must divide by n.
    Scatter {
        /// split axis
        axis: usize,
    },
    /// `all_to_all`: `split` dim must divide by n, `concat` dim grows ×n.
    AllToAll {
        /// axis each shard is split along before exchange
        split: usize,
        /// axis the exchanged pieces are concatenated along
        concat: usize,
    },
}

impl CommKind {
    /// Display name matching the schedule-op vocabulary.
    pub fn name(&self) -> &'static str {
        match self {
            CommKind::Gather { .. } => "gather",
            CommKind::Scatter { .. } => "scatter",
            CommKind::AllToAll { .. } => "all_to_all",
        }
    }

    /// Abstract shape transfer over one per-rank shard: the output shard
    /// shape, or a human-readable reason the collective cannot execute
    /// (axis out of bounds, non-divisible split dim).
    pub fn transfer(&self, shape: &[usize], n: usize) -> std::result::Result<Vec<usize>, String> {
        let check_axis = |axis: usize| -> std::result::Result<(), String> {
            if axis >= shape.len() {
                return Err(format!(
                    "axis {axis} out of bounds for rank-{} shard {shape:?}",
                    shape.len()
                ));
            }
            Ok(())
        };
        let mut out = shape.to_vec();
        match self {
            CommKind::Gather { axis } => {
                check_axis(*axis)?;
                out[*axis] *= n;
            }
            CommKind::Scatter { axis } => {
                check_axis(*axis)?;
                if out[*axis] % n != 0 {
                    return Err(format!(
                        "scatter axis {axis} has dim {} not divisible by n={n}",
                        out[*axis]
                    ));
                }
                out[*axis] /= n;
            }
            CommKind::AllToAll { split, concat } => {
                check_axis(*split)?;
                check_axis(*concat)?;
                if out[*split] % n != 0 {
                    return Err(format!(
                        "all_to_all split axis {split} has dim {} not divisible by n={n}",
                        out[*split]
                    ));
                }
                out[*split] /= n;
                out[*concat] *= n;
            }
        }
        Ok(out)
    }
}

/// An async-collective trigger: the result lands in `dest` when `id` is
/// joined by a later `Wait`.
#[derive(Clone, Debug)]
pub struct Trigger {
    /// Duality-Async collective id.
    pub id: String,
    /// Slot the joined result will overwrite.
    pub dest: String,
}

/// One lifted schedule step (the IR the abstract interpreter walks).
#[derive(Clone, Debug)]
pub struct Step {
    /// Index into the source schedule.
    pub index: usize,
    /// Human-readable actor for diagnostics (`segment 'msa_row_core'`,
    /// `gather -> 't_bias_f'`, `wait 'ag_bias'`).
    pub label: String,
    /// Slots whose *current* value this step consumes at issue time.
    /// Async collectives snapshot their input here — a later overwrite of
    /// the input slot is legal (the runtime clones shards into the comm
    /// job at the trigger).
    pub reads: Vec<String>,
    /// Slots this step synchronously overwrites at issue time.
    pub writes: Vec<String>,
    /// Async collective launched here, if any.
    pub trigger: Option<Trigger>,
    /// Async collective id joined here, if any.
    pub join: Option<String>,
    /// Collective geometry (set for sync and async collectives alike).
    pub comm: Option<CommKind>,
    /// Segment name for `Exec` steps (keys [`Program::exec_shapes`]).
    pub seg: Option<String>,
}

/// A whole lifted schedule: the unit the verifier proves hazard-free.
#[derive(Clone, Debug)]
pub struct Program {
    /// Display name (`canonical`, `manifest`, a test label).
    pub name: String,
    /// DAP degree the program runs at (shapes are per-rank shards).
    pub n: usize,
    /// Slots defined before step 0, with per-rank shard shapes where
    /// statically known (`None` = defined, shape unknown).
    pub entry: BTreeMap<String, Option<Vec<usize>>>,
    /// Per-segment output shard shapes, where known (`Exec` outputs
    /// without an entry here get unknown shapes and shape checks on
    /// them are skipped). Populated from a manifest's artifact specs
    /// when one is available.
    pub exec_shapes: BTreeMap<String, Vec<Vec<usize>>>,
    /// The lifted steps, in schedule order.
    pub steps: Vec<Step>,
}

impl Program {
    /// Lift a schedule into the effect IR. `entry` names the slots (and,
    /// where known, per-rank shard shapes) defined before the first step
    /// — the DAP block contract is `m` (s-sharded) and `z` (i-sharded).
    pub fn from_schedule(
        name: &str,
        schedule: &[ScheduleOp],
        n: usize,
        entry: &[(&str, Option<Vec<usize>>)],
    ) -> Program {
        let steps = schedule
            .iter()
            .enumerate()
            .map(|(index, op)| lift_op(index, op))
            .collect();
        Program {
            name: name.to_string(),
            n: n.max(1),
            entry: entry
                .iter()
                .map(|(s, sh)| (s.to_string(), sh.clone()))
                .collect(),
            exec_shapes: BTreeMap::new(),
            steps,
        }
    }
}

fn lift_op(index: usize, op: &ScheduleOp) -> Step {
    match op {
        ScheduleOp::Exec { seg, inputs, outputs } => Step {
            index,
            label: format!("segment '{seg}'"),
            reads: inputs.clone(),
            writes: outputs.clone(),
            trigger: None,
            join: None,
            comm: None,
            seg: Some(seg.clone()),
        },
        ScheduleOp::Gather { input, output, axis, id } => {
            lift_comm(index, input, output, id, CommKind::Gather { axis: *axis })
        }
        ScheduleOp::Scatter { input, output, axis, id } => {
            lift_comm(index, input, output, id, CommKind::Scatter { axis: *axis })
        }
        ScheduleOp::AllToAll { input, output, split, concat, id } => lift_comm(
            index,
            input,
            output,
            id,
            CommKind::AllToAll { split: *split, concat: *concat },
        ),
        ScheduleOp::Wait { id } => Step {
            index,
            label: format!("wait '{id}'"),
            reads: Vec::new(),
            writes: Vec::new(),
            trigger: None,
            join: Some(id.clone()),
            comm: None,
            seg: None,
        },
    }
}

fn lift_comm(
    index: usize,
    input: &str,
    output: &str,
    id: &Option<String>,
    kind: CommKind,
) -> Step {
    match id {
        Some(id) => Step {
            index,
            label: format!("{} '{id}' -> '{output}'", kind.name()),
            reads: vec![input.to_string()],
            writes: Vec::new(),
            trigger: Some(Trigger { id: id.clone(), dest: output.to_string() }),
            join: None,
            comm: Some(kind),
            seg: None,
        },
        None => Step {
            index,
            label: format!("{} -> '{output}'", kind.name()),
            reads: vec![input.to_string()],
            writes: vec![output.to_string()],
            trigger: None,
            join: None,
            comm: Some(kind),
            seg: None,
        },
    }
}

/// Block-entry slots for the canonical DAP program: `m` s-sharded and `z`
/// i-sharded at degree `n` (errors when `n` does not divide the preset's
/// axial dims — the same geometry rule `ParallelPlan::validate` and the
/// coordinator enforce).
pub fn canonical_entry(
    cfg: &ModelConfig,
    n: usize,
) -> Result<Vec<(&'static str, Option<Vec<usize>>)>> {
    let n = n.max(1);
    if cfg.n_seq % n != 0 || cfg.n_res % n != 0 {
        return Err(Error::Schedule(format!(
            "dap_size {n} does not divide (n_seq={}, n_res={})",
            cfg.n_seq, cfg.n_res
        )));
    }
    Ok(vec![
        ("m", Some(vec![cfg.n_seq / n, cfg.n_res, cfg.d_msa])),
        ("z", Some(vec![cfg.n_res / n, cfg.n_res, cfg.d_pair])),
    ])
}

/// The canonical per-block DAP schedule — a verbatim transcription of
/// `python/compile/dap.py::SCHEDULE` (the op list `make artifacts` exports
/// into `manifest.json`). Kept in lockstep with the python source so the
/// planner and trainer admission gates can verify the program that will
/// actually run without needing artifacts on disk; the op-census test
/// below pins the counts the python module documents.
pub fn canonical_schedule() -> Vec<ScheduleOp> {
    fn exec(seg: &str, inputs: &[&str], outputs: &[&str]) -> ScheduleOp {
        ScheduleOp::Exec {
            seg: seg.into(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
        }
    }
    fn gather(input: &str, output: &str, axis: usize, id: &str) -> ScheduleOp {
        ScheduleOp::Gather {
            input: input.into(),
            output: output.into(),
            axis,
            id: Some(id.into()),
        }
    }
    fn scatter(input: &str, output: &str, axis: usize, id: &str) -> ScheduleOp {
        ScheduleOp::Scatter {
            input: input.into(),
            output: output.into(),
            axis,
            id: Some(id.into()),
        }
    }
    fn a2a(input: &str, output: &str, split: usize, concat: usize) -> ScheduleOp {
        ScheduleOp::AllToAll {
            input: input.into(),
            output: output.into(),
            split,
            concat,
            id: None,
        }
    }
    fn a2a_async(
        input: &str,
        output: &str,
        split: usize,
        concat: usize,
        id: &str,
    ) -> ScheduleOp {
        ScheduleOp::AllToAll {
            input: input.into(),
            output: output.into(),
            split,
            concat,
            id: Some(id.into()),
        }
    }
    fn wait(id: &str) -> ScheduleOp {
        ScheduleOp::Wait { id: id.into() }
    }

    vec![
        exec("row_bias", &["z"], &["t_bias"]),
        gather("t_bias", "t_bias_f", 0, "ag_bias"),
        exec("msa_row_proj", &["m"], &["t_qkvg"]),
        wait("ag_bias"),
        exec("msa_row_core", &["m", "t_qkvg", "t_bias_f"], &["m"]),
        a2a("m", "m", 1, 0),
        exec("msa_col", &["m"], &["m"]),
        exec("msa_trans", &["m"], &["m"]),
        exec("opm_pre", &["m"], &["t_a", "t_b"]),
        gather("t_b", "t_b_f", 1, "ag_opm"),
        // m returns to s-shard for the NEXT block; overlaps the whole
        // pair stack (joined by the final wait)
        a2a_async("m", "m", 0, 1, "a2a_m"),
        wait("ag_opm"),
        exec("opm_post", &["z", "t_a", "t_b_f"], &["z"]),
        exec("tri_out_pre", &["z"], &["t_act", "t_ta", "t_tb"]),
        gather("t_tb", "t_tb_f", 0, "ag_tri"),
        wait("ag_tri"),
        exec("tri_out_post", &["z", "t_act", "t_ta", "t_tb_f"], &["z"]),
        exec("tri_in_pre", &["z"], &["t_act2", "t_part"]),
        scatter("t_part", "t_part_l", 0, "rs_tri"),
        wait("rs_tri"),
        exec("tri_in_post", &["z", "t_act2", "t_part_l"], &["z"]),
        exec("tri_start_bias", &["z"], &["t_sb"]),
        gather("t_sb", "t_sb_f", 0, "ag_sb"),
        exec("tri_start_proj", &["z"], &["t_sq"]),
        wait("ag_sb"),
        exec("tri_start_core", &["z", "t_sq", "t_sb_f"], &["z"]),
        a2a("z", "z", 1, 0),
        exec("tri_end_bias", &["z"], &["t_eb"]),
        gather("t_eb", "t_eb_f", 0, "ag_eb"),
        exec("tri_end_proj", &["z"], &["t_eq"]),
        wait("ag_eb"),
        exec("tri_end_core", &["z", "t_eq", "t_eb_f"], &["z"]),
        a2a("z", "z", 0, 1),
        exec("pair_trans", &["z"], &["z"]),
        wait("a2a_m"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_schedule_matches_python_counts() {
        // python/compile/dap.py documents 5 gather + 1 scatter + 4 a2a
        // per block forward; 18 segment executions; 6 waits (5 async
        // gathers/scatters + the overlapped a2a_m).
        let s = canonical_schedule();
        let count = |f: &dyn Fn(&ScheduleOp) -> bool| s.iter().filter(|op| f(op)).count();
        assert_eq!(count(&|op| matches!(op, ScheduleOp::Exec { .. })), 18);
        assert_eq!(count(&|op| matches!(op, ScheduleOp::Gather { .. })), 5);
        assert_eq!(count(&|op| matches!(op, ScheduleOp::Scatter { .. })), 1);
        assert_eq!(count(&|op| matches!(op, ScheduleOp::AllToAll { .. })), 4);
        assert_eq!(count(&|op| matches!(op, ScheduleOp::Wait { .. })), 6);
        assert_eq!(s.len(), 35);
    }

    #[test]
    fn shape_transfer_rules() {
        let n = 4;
        assert_eq!(
            CommKind::Gather { axis: 0 }.transfer(&[2, 8], n).unwrap(),
            vec![8, 8]
        );
        assert_eq!(
            CommKind::Scatter { axis: 1 }.transfer(&[2, 8], n).unwrap(),
            vec![2, 2]
        );
        assert_eq!(
            CommKind::AllToAll { split: 1, concat: 0 }.transfer(&[2, 8], n).unwrap(),
            vec![8, 2]
        );
        // non-divisible split dim and out-of-bounds axis both refuse
        assert!(CommKind::Scatter { axis: 0 }.transfer(&[2, 8], n).is_err());
        assert!(CommKind::Gather { axis: 2 }.transfer(&[2, 8], n).is_err());
    }

    #[test]
    fn canonical_entry_requires_divisibility() {
        let cfg = ModelConfig::tiny(); // n_seq=8, n_res=16
        let entry = canonical_entry(&cfg, 2).unwrap();
        assert_eq!(entry[0].1.as_ref().unwrap()[0], 4);
        assert_eq!(entry[1].1.as_ref().unwrap()[0], 8);
        assert!(canonical_entry(&cfg, 3).is_err());
    }

    #[test]
    fn lifting_separates_sync_and_async_effects() {
        let s = canonical_schedule();
        let p = Program::from_schedule("canonical", &s, 2, &[("m", None), ("z", None)]);
        assert_eq!(p.steps.len(), 35);
        // async gather: read at issue, no sync write, a trigger
        let ag = &p.steps[1];
        assert_eq!(ag.reads, vec!["t_bias".to_string()]);
        assert!(ag.writes.is_empty());
        assert_eq!(ag.trigger.as_ref().unwrap().id, "ag_bias");
        assert_eq!(ag.trigger.as_ref().unwrap().dest, "t_bias_f");
        // sync a2a: read + immediate write
        let a2a = &p.steps[5];
        assert_eq!(a2a.reads, vec!["m".to_string()]);
        assert_eq!(a2a.writes, vec!["m".to_string()]);
        assert!(a2a.trigger.is_none());
        // wait: pure join
        let w = &p.steps[3];
        assert_eq!(w.join.as_deref(), Some("ag_bias"));
        assert!(w.reads.is_empty() && w.writes.is_empty());
    }
}
