//! Deterministic xorshift128+ RNG — no external dependency, reproducible
//! across runs (synthetic data, property tests, shuffles).

#[derive(Clone, Debug)]
pub struct Rng {
    s0: u64,
    s1: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed so nearby seeds decorrelate
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s0 = next().max(1);
        let s1 = next().max(1);
        Rng { s0, s1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in [0, 1)
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n)
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fork a child RNG (stable: derived from the stream, not shared state).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Snapshot the generator state — with [`Rng::from_state`] this gives
    /// O(1) resumable streams (the V2 checkpoint stores per-rank data
    /// generator states so resume replays the exact same batches).
    pub fn state(&self) -> (u64, u64) {
        (self.s0, self.s1)
    }

    /// Rebuild a generator at an exact saved state (inverse of
    /// [`Rng::state`]).
    pub fn from_state(state: (u64, u64)) -> Rng {
        Rng { s0: state.0, s1: state.1 }
    }

    /// Fill with standard-normal f32s scaled by `scale`.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(21);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
