//! Collective-substrate integration: larger randomized tensors through
//! every collective, ring-vs-naive equivalence, comm-log volume accounting.

use fastfold::comm::ring::ring_all_reduce;
use fastfold::comm::{Collectives, CommKind};
use fastfold::rng::Rng;
use fastfold::tensor::HostTensor;

fn rand_shards(rng: &mut Rng, n: usize, shape: &[usize]) -> Vec<HostTensor> {
    (0..n)
        .map(|_| {
            let c: usize = shape.iter().product();
            HostTensor::new(shape.to_vec(), rng.normal_vec(c, 1.0)).unwrap()
        })
        .collect()
}

#[test]
fn gather_then_scatter_recovers_scaled_shards() {
    let mut rng = Rng::new(1);
    for n in [2usize, 3, 4, 8] {
        let c = Collectives::new(n);
        let shards = rand_shards(&mut rng, n, &[n * 3, 5]);
        let full = c.all_gather(&shards, 0).unwrap();
        // reduce_scatter of n identical full tensors = n * slice
        let back = c.reduce_scatter(&full, 0).unwrap();
        for (r, shard) in back.iter().enumerate() {
            let mut want = full[0]
                .slice_axis(0, r * (full[0].shape[0] / n), full[0].shape[0] / n)
                .unwrap();
            want.scale(1.0); // no-op, keep clone semantics clear
            let mut scaled = shard.clone();
            scaled.scale(1.0 / n as f32);
            assert!(scaled.max_abs_diff(&want) < 1e-4, "n={n} rank {r}");
        }
    }
}

#[test]
fn all_to_all_transposes_sharding_axis() {
    // m: (s, r, d) sharded on s -> all_to_all(split=1, concat=0) -> sharded on r
    let mut rng = Rng::new(2);
    let (s, r, d, n) = (8usize, 12usize, 4usize, 4usize);
    let full = HostTensor::new(
        vec![s, r, d],
        rng.normal_vec(s * r * d, 1.0),
    )
    .unwrap();
    let c = Collectives::new(n);
    let s_shards = full.split_axis(0, n).unwrap();
    let r_shards = c.all_to_all(&s_shards, 1, 0).unwrap();
    let want = full.split_axis(1, n).unwrap();
    for (a, b) in r_shards.iter().zip(want.iter()) {
        assert_eq!(a, b);
    }
    // and back
    let back = c.all_to_all(&r_shards, 0, 1).unwrap();
    for (a, b) in back.iter().zip(s_shards.iter()) {
        assert_eq!(a, b);
    }
}

#[test]
fn ring_matches_collectives_all_reduce() {
    let mut rng = Rng::new(3);
    let n = 4;
    let shards = rand_shards(&mut rng, n, &[129]); // non-divisible length
    let c = Collectives::new(n);
    let want = c.all_reduce(&shards).unwrap();
    let flat: Vec<Vec<f32>> = shards.iter().map(|t| t.data().to_vec()).collect();
    let (got, _) = ring_all_reduce(flat).unwrap();
    for g in &got {
        for (a, b) in g.iter().zip(want[0].data().iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}

#[test]
fn comm_log_totals_accumulate() {
    let mut rng = Rng::new(4);
    let c = Collectives::new(2);
    let shards = rand_shards(&mut rng, 2, &[16, 16]);
    c.all_gather(&shards, 0).unwrap();
    c.all_to_all(&shards, 0, 1).unwrap();
    c.broadcast(&shards, 0).unwrap();
    let log = c.log.lock().unwrap();
    assert_eq!(log.len(), 3);
    assert_eq!(log.count(CommKind::AllGather), 1);
    assert_eq!(log.count(CommKind::AllToAll), 1);
    assert_eq!(log.count(CommKind::Broadcast), 1);
    // all_gather wire: full*(n-1)/n = 16*16*4*2 * 1/2
    assert_eq!(log.bytes_of(CommKind::AllGather), 16 * 16 * 4 * 2 / 2);
    assert!(!log.summary().is_empty());
}
