//! Threaded schedule-executor suite — runs WITHOUT artifacts: a pure-host
//! [`SegmentRunner`] stands in for PJRT, so the rank fan-out, the comm
//! worker deferral, and every schedule-safety error path are exercised in
//! plain `cargo test`.
//!
//! The core property: for any thread budget and any DAP degree, the
//! threaded executor is *bit-for-bit* identical to the sequential path
//! (`threads = 1`) — same state tensors, same comm-log counts.

use fastfold::comm::{Collectives, CommKind};
use fastfold::dap::executor::{parallel_ranks, run_schedule, MeasuredComm, State};
use fastfold::dap::{CommCost, SegmentRunner, Timeline};
use fastfold::manifest::ScheduleOp;
use fastfold::rng::Rng;
use fastfold::tensor::HostTensor;
use fastfold::Result;
use std::sync::Mutex;

/// Deterministic pure-host segments (no PJRT): `scale` is 0.5x + 1
/// elementwise; `mix` doubles its first input and adds 1 to its second.
struct FakeRunner;

impl SegmentRunner for FakeRunner {
    fn run_segment(
        &self,
        seg: &str,
        _rank: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let map = |t: &HostTensor, f: &dyn Fn(f32) -> f32| {
            HostTensor::new(t.shape.clone(), t.data().iter().map(|&x| f(x)).collect())
        };
        match seg {
            "scale" => Ok(vec![map(&inputs[0], &|x| 0.5 * x + 1.0)?]),
            "mix" => Ok(vec![
                map(&inputs[0], &|x| 2.0 * x)?,
                map(&inputs[1], &|x| x + 1.0)?,
            ]),
            other => Err(fastfold::Error::Schedule(format!("fake: no segment '{other}'"))),
        }
    }
}

/// The reference schedule: execs interleaved with one async gather
/// (overlapped by compute), a sync scatter, and an async all-to-all.
fn schedule() -> Vec<ScheduleOp> {
    vec![
        ScheduleOp::Exec {
            seg: "scale".into(),
            inputs: vec!["m".into()],
            outputs: vec!["m".into()],
        },
        ScheduleOp::Gather {
            input: "m".into(),
            output: "g".into(),
            axis: 0,
            id: Some("h1".into()),
        },
        ScheduleOp::Exec {
            seg: "scale".into(),
            inputs: vec!["z".into()],
            outputs: vec!["z".into()],
        },
        ScheduleOp::Wait { id: "h1".into() },
        ScheduleOp::Exec {
            seg: "mix".into(),
            inputs: vec!["g".into(), "z".into()],
            outputs: vec!["m".into(), "z".into()],
        },
        ScheduleOp::Scatter { input: "m".into(), output: "m".into(), axis: 0, id: None },
        ScheduleOp::AllToAll {
            input: "z".into(),
            output: "z".into(),
            split: 1,
            concat: 0,
            id: Some("h2".into()),
        },
        ScheduleOp::Exec {
            seg: "scale".into(),
            inputs: vec!["m".into()],
            outputs: vec!["m".into()],
        },
        ScheduleOp::Wait { id: "h2".into() },
    ]
}

/// Build the block-entry state: m (16×4) s-sharded, z (16×8) i-sharded.
fn entry_state(rng: &mut Rng, n: usize) -> State {
    let m = HostTensor::new(vec![16, 4], rng.normal_vec(64, 1.0)).unwrap();
    let z = HostTensor::new(vec![16, 8], rng.normal_vec(128, 1.0)).unwrap();
    let mut state = State::new();
    state.insert("m".into(), m.split_axis(0, n).unwrap());
    state.insert("z".into(), z.split_axis(0, n).unwrap());
    state
}

fn run(
    n: usize,
    threads: usize,
    overlap: bool,
    mut state: State,
) -> Result<(State, Collectives, MeasuredComm)> {
    let comm = Collectives::new(n);
    let timeline = Mutex::new(Timeline::new(n, CommCost::cpu_calibrated(), overlap));
    let measured = Mutex::new(MeasuredComm::default());
    run_schedule(
        &schedule(), n, threads, &FakeRunner, &comm, &timeline, &measured,
        None, &mut state, None,
    )?;
    let m = *measured.lock().unwrap();
    Ok((state, comm, m))
}

#[test]
fn threaded_bitwise_equals_sequential_at_dap_2_4_8() {
    // the acceptance matrix: dap ∈ {2,4,8} × threads ∈ {2,4,8}, threaded
    // vs the threads=1 sequential reference, 10 random inputs each
    for n in [2usize, 4, 8] {
        for case in 0..10u64 {
            let mut rng = Rng::new(1000 + case);
            let state0 = entry_state(&mut rng, n);
            let (seq, seq_comm, _) = run(n, 1, true, state0.clone()).unwrap();
            for threads in [2usize, 4, 8] {
                let (thr, thr_comm, _) = run(n, threads, true, state0.clone()).unwrap();
                assert_eq!(
                    seq, thr,
                    "state diverged: n={n} threads={threads} case={case}"
                );
                let (a, b) = (seq_comm.log.lock().unwrap(), thr_comm.log.lock().unwrap());
                assert_eq!(a.len(), b.len(), "comm count: n={n} threads={threads}");
                for kind in [
                    CommKind::AllGather,
                    CommKind::ReduceScatter,
                    CommKind::AllToAll,
                ] {
                    assert_eq!(a.count(kind), b.count(kind));
                    assert_eq!(a.bytes_of(kind), b.bytes_of(kind));
                }
            }
        }
    }
}

#[test]
fn overlap_off_matches_overlap_on_numerics() {
    // Duality Async is a scheduling choice, never a numerics choice
    let mut rng = Rng::new(7);
    let n = 4;
    let state0 = entry_state(&mut rng, n);
    let (on, _, _) = run(n, 4, true, state0.clone()).unwrap();
    let (off, _, _) = run(n, 4, false, state0).unwrap();
    assert_eq!(on, off);
}

#[test]
fn deferred_collectives_are_accounted_on_the_real_clock() {
    let mut rng = Rng::new(8);
    let n = 4;
    let (_, _, measured) = run(n, 4, true, entry_state(&mut rng, n)).unwrap();
    assert!(measured.wall_seconds > 0.0);
    assert!(measured.comm_seconds > 0.0, "worker comm time must be measured");
    // exposed time can never exceed wall time
    assert!(measured.exposed_comm_seconds <= measured.wall_seconds);
}

#[test]
fn stale_read_after_async_write_errors() {
    // an Exec that reads a slot with an in-flight async write must fail,
    // not silently consume the stale pre-collective shards
    let n = 2;
    let sched = vec![
        ScheduleOp::Gather {
            input: "m".into(),
            output: "m".into(),
            axis: 0,
            id: Some("h1".into()),
        },
        ScheduleOp::Exec {
            seg: "scale".into(),
            inputs: vec!["m".into()],
            outputs: vec!["m".into()],
        },
        ScheduleOp::Wait { id: "h1".into() },
    ];
    for threads in [1usize, 2] {
        let mut rng = Rng::new(9);
        let mut state = entry_state(&mut rng, n);
        let comm = Collectives::new(n);
        let timeline = Mutex::new(Timeline::new(n, CommCost::cpu_calibrated(), true));
        let measured = Mutex::new(MeasuredComm::default());
        let err = run_schedule(
            &sched, n, threads, &FakeRunner, &comm, &timeline, &measured,
            None, &mut state, None,
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("stale read") && msg.contains("h1"), "{msg}");
    }
}

#[test]
fn write_after_write_on_inflight_slot_errors() {
    // an Exec that writes a slot with an in-flight async write must fail:
    // the join at Wait would clobber the newer value
    let n = 2;
    let sched = vec![
        ScheduleOp::Gather {
            input: "m".into(),
            output: "g".into(),
            axis: 0,
            id: Some("h1".into()),
        },
        ScheduleOp::Exec {
            seg: "scale".into(),
            inputs: vec!["z".into()],
            outputs: vec!["g".into()],
        },
        ScheduleOp::Wait { id: "h1".into() },
    ];
    for threads in [1usize, 2] {
        let mut rng = Rng::new(14);
        let mut state = entry_state(&mut rng, n);
        let comm = Collectives::new(n);
        let timeline = Mutex::new(Timeline::new(n, CommCost::cpu_calibrated(), true));
        let measured = Mutex::new(MeasuredComm::default());
        let err = run_schedule(
            &sched, n, threads, &FakeRunner, &comm, &timeline, &measured,
            None, &mut state, None,
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("write-after-write") && msg.contains("h1"), "{msg}");
    }
}

#[test]
fn wait_on_unknown_id_errors() {
    let n = 2;
    let sched = vec![ScheduleOp::Wait { id: "typo".into() }];
    let mut rng = Rng::new(10);
    let mut state = entry_state(&mut rng, n);
    let comm = Collectives::new(n);
    let timeline = Mutex::new(Timeline::new(n, CommCost::cpu_calibrated(), true));
    let measured = Mutex::new(MeasuredComm::default());
    let err = run_schedule(
        &sched, n, 2, &FakeRunner, &comm, &timeline, &measured, None, &mut state,
        None,
    )
    .unwrap_err();
    assert!(err.to_string().contains("typo"), "{err}");
}

#[test]
fn unjoined_collective_at_end_errors() {
    let n = 2;
    let sched = vec![ScheduleOp::Gather {
        input: "m".into(),
        output: "g".into(),
        axis: 0,
        id: Some("h1".into()),
    }];
    for threads in [1usize, 2] {
        let mut rng = Rng::new(11);
        let mut state = entry_state(&mut rng, n);
        let comm = Collectives::new(n);
        let timeline = Mutex::new(Timeline::new(n, CommCost::cpu_calibrated(), true));
        let measured = Mutex::new(MeasuredComm::default());
        let err = run_schedule(
            &sched, n, threads, &FakeRunner, &comm, &timeline, &measured,
            None, &mut state, None,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unjoined"), "{err}");
    }
}

#[test]
fn inflight_id_reuse_errors() {
    let n = 2;
    let sched = vec![
        ScheduleOp::Gather {
            input: "m".into(),
            output: "g".into(),
            axis: 0,
            id: Some("h1".into()),
        },
        ScheduleOp::Gather {
            input: "z".into(),
            output: "g2".into(),
            axis: 0,
            id: Some("h1".into()),
        },
    ];
    let mut rng = Rng::new(12);
    let mut state = entry_state(&mut rng, n);
    let comm = Collectives::new(n);
    let timeline = Mutex::new(Timeline::new(n, CommCost::cpu_calibrated(), true));
    let measured = Mutex::new(MeasuredComm::default());
    let err = run_schedule(
        &sched, n, 2, &FakeRunner, &comm, &timeline, &measured, None, &mut state,
        None,
    )
    .unwrap_err();
    assert!(err.to_string().contains("reused"), "{err}");
}

#[test]
fn segment_errors_surface_from_worker_threads() {
    let n = 4;
    let sched = vec![ScheduleOp::Exec {
        seg: "no-such-segment".into(),
        inputs: vec!["m".into()],
        outputs: vec!["m".into()],
    }];
    let mut rng = Rng::new(13);
    let mut state = entry_state(&mut rng, n);
    let comm = Collectives::new(n);
    let timeline = Mutex::new(Timeline::new(n, CommCost::cpu_calibrated(), true));
    let measured = Mutex::new(MeasuredComm::default());
    let err = run_schedule(
        &sched, n, 4, &FakeRunner, &comm, &timeline, &measured, None, &mut state,
        None,
    )
    .unwrap_err();
    assert!(err.to_string().contains("no-such-segment"), "{err}");
}

#[test]
fn parallel_ranks_preserves_order_and_first_error() {
    for threads in [1usize, 2, 3, 8] {
        for n in [1usize, 2, 5, 16] {
            let got = parallel_ranks(threads, n, |r| Ok(r * r)).unwrap();
            assert_eq!(got, (0..n).map(|r| r * r).collect::<Vec<_>>());
        }
    }
    // first error by rank order wins, whatever thread hit it
    let err = parallel_ranks(4, 8, |r| {
        if r >= 2 {
            Err(fastfold::Error::msg(format!("rank {r} failed")))
        } else {
            Ok(r)
        }
    })
    .unwrap_err();
    assert_eq!(err.to_string(), "rank 2 failed");
}
