//! Training-overlap acceptance suite (artifact-free, synthetic backend):
//! the bucketed per-block all-reduce, the prefetching data pipeline, and
//! bf16 mixed precision are *transparent* optimizations — they must not
//! change what is trained, only when work happens.
//!
//! Core properties:
//!  - **Bucketed ≡ monolithic, bit-for-bit** over the full layout matrix
//!    `dap ∈ {1,2,4} × dp ∈ {2,4} × accum ∈ {1,2}`: the bucket partition
//!    only re-orders *which ring call carries which leaf*; the synthetic
//!    gradients live on a dyadic grid, so per-bucket f32 sums are exact
//!    and every layout lands on identical bits.
//!  - **Prefetch ≡ inline**: the producer thread draws from the same
//!    counter-keyed stream and the trainer adopts its post-draw cursors,
//!    so batches, parameters, and V2 checkpoint state are identical.
//!  - **Resume under prefetch ≡ uninterrupted**: a checkpoint taken
//!    mid-run with the prefetcher live restores to the same bits.
//!  - **bf16 stays close to f32**: wire rounding perturbs the gradient,
//!    not the objective — losses track within a small tolerance and the
//!    loss-scale guard never fires on the synthetic stream.

use fastfold::config::{ModelConfig, Precision, TrainConfig};
use fastfold::train::{
    checkpoint, ParallelPlan, SyntheticBackend, TrainBackend, Trainer,
};

/// Small enough to split tiny's six leaves into ~5 buckets (the large
/// leaves ride alone, the small ones pack), so the schedule genuinely
/// interleaves reduction with the tape replay.
const BUCKET_MB: f64 = 1e-4;

fn quick_cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        lr: 2e-3,
        warmup_steps: 2,
        log_every: 10_000,
        checkpoint_every: 10_000,
        seed: 5,
        ..TrainConfig::default()
    }
}

fn mk(dp: usize, dap: usize, accum: usize, cfg: TrainConfig) -> Trainer<'static> {
    let model_cfg = ModelConfig::tiny();
    let params = SyntheticBackend::init_params(&model_cfg);
    let backend: Box<dyn TrainBackend> = Box::new(SyntheticBackend::new(dap));
    Trainer::with_backend(
        "tiny",
        model_cfg,
        params,
        backend,
        ParallelPlan::new(dp, dap, accum),
        cfg,
    )
    .unwrap()
}

fn assert_same_state(a: &Trainer, b: &Trainer, what: &str) {
    assert_eq!(a.step, b.step, "{what}: step");
    assert_eq!(a.cursors(), b.cursors(), "{what}: data cursors");
    for (i, (x, y)) in a.params.iter().zip(b.params.iter()).enumerate() {
        assert_eq!(x, y, "{what}: param leaf {i}");
    }
    for (i, (x, y)) in a.m.iter().zip(b.m.iter()).enumerate() {
        assert_eq!(x, y, "{what}: adam m leaf {i}");
    }
    for (i, (x, y)) in a.v.iter().zip(b.v.iter()).enumerate() {
        assert_eq!(x, y, "{what}: adam v leaf {i}");
    }
}

#[test]
fn bucketed_matches_monolithic_bitwise_across_layouts() {
    for dap in [1usize, 2, 4] {
        for dp in [2usize, 4] {
            for accum in [1usize, 2] {
                let mut mono = mk(dp, dap, accum, quick_cfg(3));
                let mut cfg = quick_cfg(3);
                cfg.bucket_mb = Some(BUCKET_MB);
                let mut bucketed = mk(dp, dap, accum, cfg);
                let rm = mono.run().unwrap();
                let rb = bucketed.run().unwrap();
                let what = format!("dap={dap} dp={dp} accum={accum}");
                assert_same_state(&mono, &bucketed, &what);
                assert_eq!(rm.final_loss, rb.final_loss, "{what}: loss");
                // the overlapped path accounts its comm honestly: the
                // ledger is populated and the exposed share is a join
                // tail, never more than the total
                assert!(rb.comm_seconds > 0.0, "{what}: comm ledger");
                assert!(
                    rb.exposed_comm_seconds <= rb.comm_seconds + 1e-12,
                    "{what}: exposed <= comm"
                );
                assert!(
                    (0.0..=1.0).contains(&rb.overlap_fraction),
                    "{what}: overlap fraction {}",
                    rb.overlap_fraction
                );
                // the monolithic reduction is fully exposed by definition
                assert_eq!(rm.exposed_comm_seconds, rm.comm_seconds, "{what}");
            }
        }
    }
}

#[test]
fn bucketed_is_thread_invariant() {
    // streaming the backward from 4 worker threads into the reducer must
    // land on the same bits as the single-threaded replay
    let mut cfg = quick_cfg(3);
    cfg.bucket_mb = Some(BUCKET_MB);
    let mut seq = mk(4, 1, 2, cfg.clone());
    let mut thr = mk(4, 1, 2, cfg).with_threads(4);
    seq.run().unwrap();
    thr.run().unwrap();
    assert_same_state(&seq, &thr, "bucketed threads=4");
}

#[test]
fn prefetch_stream_matches_inline_bitwise() {
    for (dp, accum) in [(1usize, 1usize), (2, 2), (4, 1)] {
        let mut inline = mk(dp, 1, accum, quick_cfg(3));
        let mut cfg = quick_cfg(3);
        cfg.prefetch = true;
        let mut prefetched = mk(dp, 1, accum, cfg);
        let ri = inline.run().unwrap();
        let rp = prefetched.run().unwrap();
        let what = format!("prefetch dp={dp} accum={accum}");
        assert_same_state(&inline, &prefetched, &what);
        assert_eq!(ri.final_loss, rp.final_loss, "{what}: loss");
        // the stall ledger is wired (zero is fine — the producer is a
        // step ahead; negative or NaN would mean broken accounting)
        assert!(rp.prefetch_stall_seconds >= 0.0, "{what}: stall ledger");
        assert_eq!(ri.prefetch_stall_seconds, 0.0, "{what}: inline has none");
    }
}

#[test]
fn resume_under_prefetch_matches_uninterrupted() {
    // a V2 checkpoint taken while the prefetcher is a step ahead must
    // capture the *post-draw* cursors, so the resumed run replays the
    // exact remainder of the stream
    let dir = std::env::temp_dir().join("ff_train_overlap_resume");
    std::fs::remove_dir_all(&dir).ok();
    let dir_s = dir.to_str().unwrap().to_string();
    let mut cfg = quick_cfg(6);
    cfg.prefetch = true;
    cfg.bucket_mb = Some(BUCKET_MB);
    cfg.checkpoint_every = 3;
    cfg.checkpoint_dir = Some(dir_s.clone());

    let mut full = mk(2, 2, 2, cfg.clone());
    full.run().unwrap();

    let mut resumed = mk(2, 2, 2, cfg.clone());
    let state = checkpoint::load_full(&dir_s, "tiny", 3).unwrap();
    assert_eq!(state.step, 3);
    resumed.restore(state).unwrap();
    let report = resumed.run().unwrap();
    assert_eq!(report.steps, 3, "resume executes only the remainder");
    assert_same_state(&full, &resumed, "resume under prefetch");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn bf16_tracks_f32_loss_within_tolerance() {
    // full optimized stack (bf16 wire + buckets + prefetch) vs the f32
    // synchronous baseline: same data stream, same objective; the bf16
    // grid only perturbs gradients at ~2^-8 relative, so 4 steps of
    // drift stays small
    let mut f32_t = mk(2, 1, 2, quick_cfg(4));
    let mut cfg = quick_cfg(4);
    cfg.precision = Precision::Bf16;
    cfg.prefetch = true;
    cfg.bucket_mb = Some(BUCKET_MB);
    let mut bf16_t = mk(2, 1, 2, cfg);
    let rf = f32_t.run().unwrap();
    let rb = bf16_t.run().unwrap();
    assert_eq!(rf.precision, "f32");
    assert_eq!(rb.precision, "bf16");
    assert_eq!(rb.skipped_steps, 0, "loss-scale guard must not fire");
    assert!(rf.final_loss.is_finite() && rb.final_loss.is_finite());
    let rel = (rf.final_loss - rb.final_loss).abs() / rf.final_loss.abs().max(1e-6);
    assert!(rel < 5e-2, "bf16 loss drift {rel} (f32 {} bf16 {})", rf.final_loss, rb.final_loss);
    // parameters drift but stay close: max relative leaf deviation
    for (i, (x, y)) in f32_t.params.iter().zip(bf16_t.params.iter()).enumerate() {
        for (a, b) in x.data().iter().zip(y.data().iter()) {
            assert!(
                (a - b).abs() <= 2e-2 * a.abs().max(1.0),
                "leaf {i}: f32 {a} vs bf16 {b}"
            );
        }
    }
}

#[test]
fn bf16_wire_is_exactly_half_of_f32() {
    let mut cfg32 = quick_cfg(2);
    cfg32.bucket_mb = Some(BUCKET_MB);
    let mut cfg16 = cfg32.clone();
    cfg16.precision = Precision::Bf16;
    let mut t32 = mk(4, 1, 1, cfg32);
    let mut t16 = mk(4, 1, 1, cfg16);
    let r32 = t32.run().unwrap();
    let r16 = t16.run().unwrap();
    assert!(r32.wire_bytes > 0);
    assert_eq!(r16.wire_bytes * 2, r32.wire_bytes, "bf16 wire halves bytes");
}
