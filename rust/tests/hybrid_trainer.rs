//! Hybrid-trainer suite — runs WITHOUT artifacts: the pure-host
//! [`SyntheticBackend`] stands in for PJRT (mirroring the `FakeRunner`
//! pattern of `threaded_executor.rs`), so the plan routing, global-stream
//! data assignment, gradient accumulation, DP ring reduction, Adam, the
//! stage schedule, and V2 checkpoint resume are exercised in plain
//! `cargo test`.
//!
//! Core property (the acceptance matrix): every hybrid layout
//! `dap ∈ {1,2,4} × dp ∈ {1,2} × accum ∈ {1,2}` produces **bit-for-bit**
//! identical parameters to the sequential `dp=1, dap=1` baseline at
//! matched effective batch — the micro-batch stream is a pure function of
//! the effective batch, the synthetic gradients live on an integer grid
//! (sums are exact in f32, so no fold order can change the bits), and the
//! Adam update then sees identical inputs in every layout.

use fastfold::config::{ModelConfig, TrainConfig};
use fastfold::perfmodel::MemoryModel;
use fastfold::rng::Rng;
use fastfold::train::{
    checkpoint, LrSchedule, ParallelPlan, Stage, SyntheticBackend, TrainBackend,
    TrainSchedule, Trainer,
};

fn quick_cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        lr: 2e-3,
        warmup_steps: 2,
        log_every: 10_000,
        checkpoint_every: 10_000,
        seed: 5,
        ..TrainConfig::default()
    }
}

/// A synthetic-backend trainer over the tiny preset.
fn mk(dp: usize, dap: usize, accum: usize, cfg: TrainConfig) -> Trainer<'static> {
    let model_cfg = ModelConfig::tiny();
    let params = SyntheticBackend::init_params(&model_cfg);
    let backend: Box<dyn TrainBackend> = Box::new(SyntheticBackend::new(dap));
    Trainer::with_backend(
        "tiny",
        model_cfg,
        params,
        backend,
        ParallelPlan::new(dp, dap, accum),
        cfg,
    )
    .unwrap()
}

fn assert_same_state(a: &Trainer, b: &Trainer, what: &str) {
    assert_eq!(a.step, b.step, "{what}: step");
    assert_eq!(a.params.len(), b.params.len(), "{what}: leaf count");
    for (i, (x, y)) in a.params.iter().zip(b.params.iter()).enumerate() {
        assert_eq!(x, y, "{what}: param leaf {i}");
    }
    for (i, (x, y)) in a.m.iter().zip(b.m.iter()).enumerate() {
        assert_eq!(x, y, "{what}: adam m leaf {i}");
    }
    for (i, (x, y)) in a.v.iter().zip(b.v.iter()).enumerate() {
        assert_eq!(x, y, "{what}: adam v leaf {i}");
    }
}

#[test]
fn hybrid_matrix_bitwise_matches_sequential_baseline() {
    // dap ∈ {1,2,4} × dp ∈ {1,2} × accum ∈ {1,2}, each vs the dp=1, dap=1
    // baseline at the same effective batch, 3 optimizer steps
    for dap in [1usize, 2, 4] {
        for dp in [1usize, 2] {
            for accum in [1usize, 2] {
                let e = dp * accum;
                let mut base = mk(1, 1, e, quick_cfg(3));
                let mut hyb = mk(dp, dap, accum, quick_cfg(3));
                let rb = base.run().unwrap();
                let rh = hyb.run().unwrap();
                let what = format!("dap={dap} dp={dp} accum={accum}");
                assert_eq!(rb.steps, 3, "{what}");
                assert_eq!(rh.steps, 3, "{what}");
                assert_eq!(
                    rb.final_loss.to_bits(),
                    rh.final_loss.to_bits(),
                    "{what}: loss"
                );
                assert_same_state(&base, &hyb, &what);
                // loss history matches step-for-step, bit-for-bit
                for ((sa, la), (sb, lb)) in
                    base.history.iter().zip(hyb.history.iter())
                {
                    assert_eq!(sa, sb, "{what}");
                    assert_eq!(la.to_bits(), lb.to_bits(), "{what}: history");
                }
                // DP wire moves only when there are real replicas
                assert_eq!(rh.wire_bytes > 0, dp > 1, "{what}: dp wire");
            }
        }
    }
}

#[test]
fn hybrid_step_is_thread_invariant() {
    let mut seq = mk(2, 2, 2, quick_cfg(3));
    let mut thr = mk(2, 2, 2, quick_cfg(3)).with_threads(4);
    seq.run().unwrap();
    thr.run().unwrap();
    assert_same_state(&seq, &thr, "threads=4");
}

#[test]
fn resume_equals_uninterrupted_bitwise() {
    // the V2 checkpoint regression: params + Adam moments + step + data
    // cursors round-trip, so a resumed run is bit-for-bit the
    // uninterrupted one (V1 lost Adam/step/warmup/data position)
    let dir = std::env::temp_dir().join("ff_hybrid_resume");
    std::fs::remove_dir_all(&dir).ok();
    let dir_s = dir.to_str().unwrap().to_string();
    let mut cfg = quick_cfg(6);
    cfg.checkpoint_every = 3;
    cfg.checkpoint_dir = Some(dir_s.clone());

    let mut full = mk(2, 2, 2, cfg.clone());
    full.run().unwrap();

    let mut resumed = mk(2, 2, 2, cfg.clone());
    assert_eq!(checkpoint::latest_step(&dir_s, "tiny").unwrap(), Some(6));
    let state = checkpoint::load_full(&dir_s, "tiny", 3).unwrap();
    assert_eq!(state.step, 3);
    resumed.restore(state).unwrap();
    assert_eq!(resumed.step, 3);
    let report = resumed.run().unwrap();
    assert_eq!(report.steps, 3, "resume executes only the remainder");
    assert_same_state(&full, &resumed, "resume");
    assert_eq!(full.cursors(), resumed.cursors(), "data cursors");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn restore_rejects_mismatched_plan_and_preset() {
    let dir = std::env::temp_dir().join("ff_hybrid_restore_guard");
    std::fs::remove_dir_all(&dir).ok();
    let dir_s = dir.to_str().unwrap().to_string();
    let mut cfg = quick_cfg(2);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir_s.clone());
    mk(2, 1, 1, cfg).run().unwrap();
    let state = checkpoint::load_full(&dir_s, "tiny", 2).unwrap();
    // dp=1 trainer cannot take a 2-rank data stream
    let err = mk(1, 1, 1, quick_cfg(2)).restore(state.clone()).unwrap_err();
    assert!(err.to_string().contains("dp="), "{err}");
    // a changed accum shifts the per-rank cursor stride — rejected, not
    // silently misaligned
    let err = mk(2, 1, 2, quick_cfg(2)).restore(state).unwrap_err();
    assert!(err.to_string().contains("accum="), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn two_stage_schedule_runs_and_reports_actual_steps() {
    // same-preset stages with different LR shapes: the report counts the
    // steps actually executed (not cfg.steps) and the LR actually applied
    let sched = TrainSchedule {
        stages: vec![
            Stage {
                name: "initial".into(),
                preset: "tiny".into(),
                steps: 2,
                lr: LrSchedule::warmup_only(1e-3, 2),
            },
            Stage {
                name: "finetune".into(),
                preset: "tiny".into(),
                steps: 3,
                lr: LrSchedule {
                    base_lr: 5e-4,
                    warmup_steps: 0,
                    decay_after: Some(2),
                    decay_factor: 0.5,
                },
            },
        ],
    };
    let mut cfg = quick_cfg(999); // cfg.steps is NOT what runs
    cfg.warmup_steps = 2;
    let mut t = mk(2, 1, 1, cfg);
    let report = t.run_schedule(&sched).unwrap();
    assert_eq!(report.steps, 5, "executed = schedule total, not cfg.steps");
    assert_eq!(t.step, 5);
    assert_eq!(t.stage, 2);
    // final stage step index 2 hits the 0.5x decay: 5e-4 * 0.5
    assert!((report.final_lr - 2.5e-4).abs() < 1e-9, "{}", report.final_lr);
    // a finished trainer re-run executes nothing and changes nothing
    let params = t.params.clone();
    let again = t.run_schedule(&sched).unwrap();
    assert_eq!(again.steps, 0);
    assert_eq!(t.params, params);
}

#[test]
fn schedule_resume_mid_stage_matches_uninterrupted() {
    let sched = TrainSchedule {
        stages: vec![
            Stage {
                name: "a".into(),
                preset: "tiny".into(),
                steps: 2,
                lr: LrSchedule::warmup_only(2e-3, 2),
            },
            Stage {
                name: "b".into(),
                preset: "tiny".into(),
                steps: 4,
                lr: LrSchedule::warmup_only(1e-3, 0),
            },
        ],
    };
    let dir = std::env::temp_dir().join("ff_hybrid_stage_resume");
    std::fs::remove_dir_all(&dir).ok();
    let dir_s = dir.to_str().unwrap().to_string();
    let mut cfg = quick_cfg(0);
    cfg.checkpoint_every = 4; // lands mid-stage-b (global step 4)
    cfg.checkpoint_dir = Some(dir_s.clone());

    let mut full = mk(2, 2, 1, cfg.clone());
    full.run_schedule(&sched).unwrap();

    let mut resumed = mk(2, 2, 1, cfg);
    let state = checkpoint::load_full(&dir_s, "tiny", 4).unwrap();
    assert_eq!(state.stage, 1);
    assert_eq!(state.steps_in_stage, 2);
    resumed.restore(state).unwrap();
    let report = resumed.run_schedule(&sched).unwrap();
    assert_eq!(report.steps, 2);
    assert_same_state(&full, &resumed, "stage resume");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn applied_lr_is_the_pre_step_schedule_value() {
    // regression for the lr_at(self.step - 1) post-bump recompute: the
    // report carries the LR the optimizer actually used
    let mut cfg = quick_cfg(1);
    cfg.lr = 1e-3;
    cfg.warmup_steps = 4;
    let mut t = mk(1, 1, 1, cfg);
    t.train_step().unwrap();
    // step 0 of a 4-step warmup: base * 1/4
    assert!((t.last_lr - 0.25e-3).abs() < 1e-10, "{}", t.last_lr);
}

// ------------------------------------------------------- plan properties

#[test]
fn prop_parallel_plan_validation() {
    // hand-rolled property sweep (proptests.rs pattern): validation
    // accepts exactly the structurally sound plans, and the modeled
    // per-device training memory never grows with more DAP sharding
    let mut rng = Rng::new(77);
    let mem = MemoryModel::default();
    for cfg in [ModelConfig::tiny(), ModelConfig::initial_training()] {
        for _ in 0..200 {
            let dp = rng.below(5); // 0..4
            let dap = rng.below(9); // 0..8
            let accum = rng.below(4);
            let plan = ParallelPlan::new(dp, dap, accum);
            let ok = plan.validate(&cfg).is_ok();
            let expect = dp >= 1
                && dap >= 1
                && accum >= 1
                && cfg.n_seq % dap == 0
                && cfg.n_res % dap == 0;
            assert_eq!(ok, expect, "dp={dp} dap={dap} accum={accum} {}", cfg.name);
            if ok {
                assert_eq!(plan.gpus(), dp * dap);
                assert_eq!(plan.effective_batch(), dp * accum);
            }
        }
        // memory monotonicity over the valid dap ladder
        let mut prev = f64::INFINITY;
        for dap in [1usize, 2, 4] {
            let plan = ParallelPlan::new(1, dap, 1);
            if plan.validate(&cfg).is_err() {
                continue;
            }
            let need = plan.train_bytes_per_device(&cfg, &mem);
            assert!(
                need <= prev + 1e-6,
                "{}: dap={dap} need {need} > prev {prev}",
                cfg.name
            );
            prev = need;
        }
    }
}

#[test]
fn synthetic_loss_depends_on_params() {
    // the loss is ⟨params, grads⟩ — perturbing a parameter must move it
    let model_cfg = ModelConfig::tiny();
    let params = SyntheticBackend::init_params(&model_cfg);
    let be = SyntheticBackend::new(1);
    let mut gen = fastfold::train::DataGen::new(model_cfg, 5);
    let batch = gen.next_batch();
    let (l0, g) = be.grad(&params, &batch).unwrap();
    let mut bumped = params.clone();
    // bump along a coordinate with a non-zero gradient so ⟨p, g⟩ moves
    let (leaf, idx) = g
        .iter()
        .enumerate()
        .find_map(|(j, gl)| {
            gl.data().iter().position(|&x| x != 0.0).map(|i| (j, i))
        })
        .expect("some nonzero gradient coordinate");
    bumped[leaf].data_mut()[idx] += 1.0;
    let (l1, _) = be.grad(&bumped, &batch).unwrap();
    assert_ne!(l0.to_bits(), l1.to_bits());
}
