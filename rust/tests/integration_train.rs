//! Training integration: the DP trainer (grad_step → ring all-reduce →
//! adam_update, all via PJRT) must reduce the loss on synthetic data, be
//! reproducible, and checkpoint-roundtrip.

use fastfold::config::TrainConfig;
use fastfold::runtime::Runtime;
use fastfold::train::Trainer;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

fn quick_cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        lr: 2e-3,
        warmup_steps: 2,
        log_every: 1000,
        checkpoint_every: 10_000,
        checkpoint_dir: None,
        seed: 5,
        grad_clip: Some(1.0),
    }
}

#[test]
fn loss_decreases_single_worker() {
    let Some(rt) = runtime() else { return };
    let mut t = Trainer::new(&rt, "tiny", 1, quick_cfg(12)).unwrap();
    let report = t.run().unwrap();
    assert!(
        report.final_loss < report.initial_loss,
        "{} -> {}",
        report.initial_loss,
        report.final_loss
    );
    assert!(report.final_loss.is_finite());
}

#[test]
fn dp2_matches_loss_trajectory_shape_and_reduces() {
    let Some(rt) = runtime() else { return };
    let mut t = Trainer::new(&rt, "tiny", 2, quick_cfg(8)).unwrap();
    let report = t.run().unwrap();
    assert!(report.final_loss < report.initial_loss);
    // ring all-reduce actually moved gradient bytes
    assert!(report.wire_bytes > 0);
}

#[test]
fn training_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let run = || {
        let mut t = Trainer::new(&rt, "tiny", 1, quick_cfg(4)).unwrap();
        t.run().unwrap().final_loss
    };
    assert_eq!(run(), run());
}

#[test]
fn dp_grad_equals_mean_of_worker_grads() {
    // DP=2 with identical per-worker data seeds must equal DP=1 math:
    // verified indirectly — same-seed generators produce identical batches,
    // so all-reduced mean grads == single grads and losses match exactly.
    let Some(rt) = runtime() else { return };
    let mut t1 = Trainer::new(&rt, "tiny", 1, quick_cfg(3)).unwrap();
    let mut t2 = Trainer::new(&rt, "tiny", 2, quick_cfg(3)).unwrap();
    // force both DP workers onto the same data stream as the single worker
    // by reusing seed spacing: worker r uses seed+1000r, so instead compare
    // that DP loss is finite and close in magnitude after equal steps.
    let r1 = t1.run().unwrap();
    let r2 = t2.run().unwrap();
    assert!(r1.final_loss.is_finite() && r2.final_loss.is_finite());
    assert!((r1.final_loss - r2.final_loss).abs() < 1.0);
}

#[test]
fn threaded_train_step_bitwise_matches_sequential_dp_2_4() {
    // the threaded rank executor must not change training numerics: one
    // step at dp ∈ {2,4} with threads=1 vs threads=4, params bit-for-bit
    let Some(rt) = runtime() else { return };
    for dp in [2usize, 4] {
        let mut seq = Trainer::new(&rt, "tiny", dp, quick_cfg(1)).unwrap().with_threads(1);
        let mut thr = Trainer::new(&rt, "tiny", dp, quick_cfg(1)).unwrap().with_threads(4);
        let l_seq = seq.train_step().unwrap();
        let l_thr = thr.train_step().unwrap();
        assert_eq!(l_seq.to_bits(), l_thr.to_bits(), "dp={dp} loss diverged");
        assert_eq!(seq.params.len(), thr.params.len());
        for (i, (a, b)) in seq.params.iter().zip(thr.params.iter()).enumerate() {
            assert_eq!(a, b, "dp={dp} param leaf {i} diverged");
        }
        assert_eq!(seq.wire_bytes, thr.wire_bytes, "dp={dp} wire accounting");
    }
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join("ff_train_ckpt");
    let dir_s = dir.to_str().unwrap().to_string();
    let mut cfg = quick_cfg(4);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir_s.clone());
    let mut t = Trainer::new(&rt, "tiny", 1, cfg).unwrap();
    t.run().unwrap();
    let (step, params) = fastfold::train::checkpoint::load(&dir_s, "tiny", 4).unwrap();
    assert_eq!(step, 4);
    assert_eq!(params.len(), t.params.len());
    for (a, b) in params.iter().zip(t.params.iter()) {
        assert_eq!(a, b);
    }
    std::fs::remove_dir_all(dir).ok();
}
