//! Training integration (artifact-gated): the trainer over real PJRT
//! executables must reduce the loss on synthetic data, be reproducible,
//! stay bit-for-bit across thread budgets, checkpoint-resume exactly, and
//! — when the hybrid artifacts are exported — route `dap > 1` replicas
//! through the DAP coordinator/tape with parameters bit-for-bit equal to
//! the dense baseline at matched effective batch.

use fastfold::config::TrainConfig;
use fastfold::runtime::Runtime;
use fastfold::train::{checkpoint, ParallelPlan, Trainer};

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

fn quick_cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        lr: 2e-3,
        warmup_steps: 2,
        log_every: 1000,
        checkpoint_every: 10_000,
        seed: 5,
        ..TrainConfig::default()
    }
}

#[test]
fn loss_decreases_single_worker() {
    let Some(rt) = runtime() else { return };
    let mut t = Trainer::new(&rt, "tiny", 1, quick_cfg(12)).unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.steps, 12);
    assert!(
        report.final_loss < report.initial_loss,
        "{} -> {}",
        report.initial_loss,
        report.final_loss
    );
    assert!(report.final_loss.is_finite());
}

#[test]
fn dp2_reduces_loss_and_moves_ring_wire() {
    let Some(rt) = runtime() else { return };
    let mut t = Trainer::new(&rt, "tiny", 2, quick_cfg(8)).unwrap();
    let report = t.run().unwrap();
    assert!(report.final_loss < report.initial_loss);
    // ring all-reduce actually moved gradient bytes; dense path moves no
    // model-parallel bytes
    assert!(report.wire_bytes > 0);
    assert_eq!(report.wire_dap_bytes, 0);
}

#[test]
fn training_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let run = || {
        let mut t = Trainer::new(&rt, "tiny", 1, quick_cfg(4)).unwrap();
        t.run().unwrap().final_loss
    };
    assert_eq!(run(), run());
}

#[test]
fn accumulation_matches_dp_at_same_effective_batch() {
    // dp=2 × accum=1 and dp=1 × accum=2 consume the same global stream;
    // on real f32 grads the two fold orders agree to float tolerance
    let Some(rt) = runtime() else { return };
    let mut a = Trainer::hybrid(&rt, "tiny", ParallelPlan::new(2, 1, 1), true, quick_cfg(3))
        .unwrap();
    let mut b = Trainer::hybrid(&rt, "tiny", ParallelPlan::new(1, 1, 2), true, quick_cfg(3))
        .unwrap();
    let ra = a.run().unwrap();
    let rb = b.run().unwrap();
    assert!((ra.final_loss - rb.final_loss).abs() < 1e-4);
    for (x, y) in a.params.iter().zip(b.params.iter()) {
        assert!(x.max_abs_diff(y) < 1e-4);
    }
}

#[test]
fn threaded_train_step_bitwise_matches_sequential_dp_2_4() {
    // the threaded rank executor must not change training numerics: one
    // step at dp ∈ {2,4} with threads=1 vs threads=4, params bit-for-bit
    let Some(rt) = runtime() else { return };
    for dp in [2usize, 4] {
        let mut seq = Trainer::new(&rt, "tiny", dp, quick_cfg(1)).unwrap().with_threads(1);
        let mut thr = Trainer::new(&rt, "tiny", dp, quick_cfg(1)).unwrap().with_threads(4);
        let l_seq = seq.train_step().unwrap();
        let l_thr = thr.train_step().unwrap();
        assert_eq!(l_seq.to_bits(), l_thr.to_bits(), "dp={dp} loss diverged");
        assert_eq!(seq.params.len(), thr.params.len());
        for (i, (a, b)) in seq.params.iter().zip(thr.params.iter()).enumerate() {
            assert_eq!(a, b, "dp={dp} param leaf {i} diverged");
        }
        assert_eq!(seq.wire_dp_bytes, thr.wire_dp_bytes, "dp={dp} wire accounting");
    }
}

#[test]
fn hybrid_dap2_routes_through_coordinator_and_matches_dense() {
    // the tentpole: dap=2 replicas run embed → DAP blocks (tape) → heads
    // VJP → reverse replay; parameters land bit-for-bit on the dense
    // baseline at matched effective batch, and DAP wire is accounted
    let Some(rt) = runtime() else { return };
    if !rt.manifest.artifacts.contains_key("tiny/loss_head_grad") {
        eprintln!("skipping: hybrid artifacts (loss_head_grad/embed_bwd) not exported");
        return;
    }
    let mut dense =
        Trainer::hybrid(&rt, "tiny", ParallelPlan::new(1, 1, 1), true, quick_cfg(2))
            .unwrap();
    let mut hybrid =
        Trainer::hybrid(&rt, "tiny", ParallelPlan::new(1, 2, 1), true, quick_cfg(2))
            .unwrap();
    assert_eq!(hybrid.backend_name(), "dap2");
    let ld = dense.run().unwrap();
    let lh = hybrid.run().unwrap();
    assert!(lh.wire_dap_bytes > 0, "DAP collectives must be accounted");
    assert_eq!(ld.wire_bytes, 0);
    // dense runs one fused XLA program, hybrid runs the segment
    // decomposition — agreement is float-tight, not bitwise (the bitwise
    // layout-equivalence contract is enforced in hybrid_trainer.rs where
    // the per-micro math is identical by construction)
    assert!(
        (ld.final_loss - lh.final_loss).abs() < 1e-4,
        "hybrid loss diverged from dense: {} vs {}",
        ld.final_loss,
        lh.final_loss
    );
    for (i, (a, b)) in dense.params.iter().zip(hybrid.params.iter()).enumerate() {
        assert!(a.max_abs_diff(b) < 1e-4, "param leaf {i} diverged");
    }

    // but the hybrid path at the SAME degree is deterministic bit-for-bit
    let mut again =
        Trainer::hybrid(&rt, "tiny", ParallelPlan::new(1, 2, 1), true, quick_cfg(2))
            .unwrap();
    let la = again.run().unwrap();
    assert_eq!(la.final_loss.to_bits(), lh.final_loss.to_bits());
    for (a, b) in again.params.iter().zip(hybrid.params.iter()) {
        assert_eq!(a, b);
    }
}

#[test]
fn checkpoint_resume_through_trainer_is_bitwise() {
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join("ff_train_ckpt");
    std::fs::remove_dir_all(&dir).ok();
    let dir_s = dir.to_str().unwrap().to_string();
    let mut cfg = quick_cfg(4);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir_s.clone());
    let mut full = Trainer::new(&rt, "tiny", 1, cfg.clone()).unwrap();
    full.run().unwrap();

    // params-only reader still works against the V2 blob
    let (step, params) = checkpoint::load(&dir_s, "tiny", 4).unwrap();
    assert_eq!(step, 4);
    assert_eq!(params.len(), full.params.len());
    for (a, b) in params.iter().zip(full.params.iter()) {
        assert_eq!(a, b);
    }

    // full-state resume from the midpoint reproduces the run bit-for-bit
    let mut resumed = Trainer::new(&rt, "tiny", 1, cfg).unwrap();
    resumed.restore(checkpoint::load_full(&dir_s, "tiny", 2).unwrap()).unwrap();
    let report = resumed.run().unwrap();
    assert_eq!(report.steps, 2);
    assert_eq!(full.step, resumed.step);
    for (a, b) in full.params.iter().zip(resumed.params.iter()) {
        assert_eq!(a, b);
    }
    for (a, b) in full.m.iter().zip(resumed.m.iter()) {
        assert_eq!(a, b);
    }
    for (a, b) in full.v.iter().zip(resumed.v.iter()) {
        assert_eq!(a, b);
    }
    std::fs::remove_dir_all(dir).ok();
}
