//! Serving-engine suite.
//!
//! Artifact-free half: a pure-host [`BackendFactory`] fake stands in for
//! PJRT (same seam idea as the threaded-executor suite), so placement,
//! admission control, scheduling determinism, and the threaded drain loop
//! are exercised in plain `cargo test`. The core property: the same
//! request set produces the same backend choices, the same schedule, and
//! bit-for-bit the same outputs at any `--threads` budget.
//!
//! Artifact-gated half: with `artifacts/` present, every engine backend's
//! output is bit-for-bit identical to the corresponding legacy
//! single-path invocation (`single_device_forward`, the DAP coordinator).

use fastfold::config::{ModelConfig, RunConfig};
use fastfold::dap::DapCoordinator;
use fastfold::inference::engine::{
    BackendFactory, BackendKind, Engine, InferBackend, InferOutput, InferRequest, Placement,
    SchedPolicy,
};
use fastfold::inference::single_device_forward;
use fastfold::runtime::Runtime;
use fastfold::train::DataGen;
use fastfold::{Error, HostTensor, IntTensor, Result};

// ---------------------------------------------------------------- helpers

/// A Runtime over a minimal (artifact-free) manifest: enough for the
/// engine's planning/scheduling machinery, which never executes HLO.
fn stub_runtime(tag: &str) -> (Runtime, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(format!(
        "fastfold_serve_{tag}_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts":{},"params":{},"dap_schedule":[],"configs":{}}"#,
    )
    .unwrap();
    let rt = Runtime::new(dir.to_str().unwrap()).unwrap();
    (rt, dir)
}

/// Real-artifact runtime, or None (test self-skips like the other
/// integration suites).
fn artifact_runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

/// Deterministic pure-host backend: output derives only from the request
/// identity, the chosen backend, and the token stream — never from
/// thread timing.
struct FakeBackend {
    name: String,
    seed: u64,
    priority: u32,
}

impl InferBackend for FakeBackend {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn infer(&self, tokens: &IntTensor) -> Result<InferOutput> {
        let a = self.seed as f32;
        let b: f32 = tokens.data.iter().map(|&t| t as f32).sum();
        let c = self.name.bytes().map(|x| x as u32).sum::<u32>() as f32;
        let m = HostTensor::new(vec![2, 2], vec![a, b, c, self.priority as f32])?;
        let z = HostTensor::new(vec![2], vec![a + b, c * 0.5])?;
        Ok(InferOutput {
            msa_logits: m,
            dist_logits: z,
            note: Some(format!("fake:{}", self.name)),
        })
    }
}

struct FakeFactory;

impl BackendFactory for FakeFactory {
    fn make<'a>(
        &'a self,
        req: &InferRequest,
        placement: &Placement,
        _rank_threads: usize,
    ) -> Result<Box<dyn InferBackend + 'a>> {
        Ok(Box::new(FakeBackend {
            name: placement.backend.name(),
            seed: req.seed,
            priority: req.priority,
        }))
    }
}

/// The mixed batch every determinism test drains: short, long/chunked,
/// DAP-worthy, and one inadmissible request.
fn mixed_batch() -> Vec<InferRequest> {
    let with_len = |id: &str, len: Option<usize>, seed: u64| {
        let mut r = InferRequest::new(id, "tiny");
        r.model_len = len;
        r.seed = seed;
        r
    };
    vec![
        with_len("preset-short", None, 3),
        with_len("short-512", Some(512), 5),
        with_len("long-2048", Some(2048), 7),
        with_len("dist-4096", Some(4096), 11),
        with_len("dist-3072", Some(3072), 13),
        with_len("too-big-8192", Some(8192), 17),
    ]
}

fn engine_with(rt: &Runtime, policy: SchedPolicy, threads: usize) -> Engine<'_> {
    let cfg = RunConfig {
        serve: fastfold::config::ServeConfig { policy, ..Default::default() },
        parallel: fastfold::config::ParallelConfig { threads, ..Default::default() },
        ..Default::default()
    };
    Engine::new(rt, &cfg).expect("engine")
}

// ------------------------------------------------------- artifact-free

#[test]
fn placement_covers_all_backends_and_rejects() {
    let (rt, dir) = stub_runtime("placement");
    let engine = engine_with(&rt, SchedPolicy::Fifo, 1);
    let reqs = mixed_batch();
    let report = engine.serve_with(&reqs, &FakeFactory).unwrap();

    let backend = |i: usize| {
        report.outcomes[i]
            .placement
            .as_ref()
            .map(|p: &Placement| p.backend.clone())
    };
    assert_eq!(backend(0), Some(BackendKind::SingleDevice));
    assert_eq!(backend(1), Some(BackendKind::SingleDevice));
    assert_eq!(backend(2), Some(BackendKind::Chunked));
    assert_eq!(backend(3), Some(BackendKind::Dap(8)));
    assert!(matches!(backend(4), Some(BackendKind::Dap(n)) if n <= 8));
    // admission control: the 8192-residue request is rejected with the
    // sim-OOM verdict, not executed
    assert!(backend(5).is_none());
    assert!(matches!(
        report.outcomes[5].output,
        Err(Error::SimOom { .. })
    ));
    assert_eq!(report.completed(), 5);
    assert_eq!(report.order.len(), 5);

    // metrics: every admitted request contributes modeled flops; the
    // aggregate throughput figure is finite and positive
    assert!(report.stats.total_modeled_flops() > 0.0);
    assert!(report.aggregate_pflops() > 0.0);
    let mix = report.stats.backend_mix();
    assert!(
        mix.contains("single x2") && mix.contains("chunked x1") && mix.contains("rejected x1"),
        "{mix}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn same_batch_same_outputs_regardless_of_threads() {
    // satellite acceptance: same request set ⇒ same backend choices and
    // bit-for-bit same outputs at any --threads, under both policies
    let (rt, dir) = stub_runtime("determinism");
    let reqs = mixed_batch();
    for policy in [SchedPolicy::Fifo, SchedPolicy::Sjf] {
        let reference = engine_with(&rt, policy, 1)
            .serve_with(&reqs, &FakeFactory)
            .unwrap();
        for threads in [2usize, 4, 8] {
            let run = engine_with(&rt, policy, threads)
                .serve_with(&reqs, &FakeFactory)
                .unwrap();
            assert_eq!(run.order, reference.order, "schedule @ threads={threads}");
            for (a, b) in run.outcomes.iter().zip(reference.outcomes.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.placement.as_ref().map(|p| p.backend.clone()),
                    b.placement.as_ref().map(|p| p.backend.clone()),
                    "backend for '{}' @ threads={threads}",
                    a.id
                );
                match (&a.output, &b.output) {
                    (Ok((am, az)), Ok((bm, bz))) => {
                        // bit-for-bit: exact data equality, not tolerance
                        assert_eq!(am.data(), bm.data(), "'{}' @ threads={threads}", a.id);
                        assert_eq!(az.data(), bz.data(), "'{}' @ threads={threads}", a.id);
                    }
                    (Err(ae), Err(be)) => assert_eq!(ae.to_string(), be.to_string()),
                    _ => panic!("disposition of '{}' changed with threads", a.id),
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sjf_schedules_short_jobs_first_fifo_preserves_arrival() {
    let (rt, dir) = stub_runtime("policies");
    let reqs = mixed_batch();
    let fifo = engine_with(&rt, SchedPolicy::Fifo, 2)
        .serve_with(&reqs, &FakeFactory)
        .unwrap();
    // FIFO: admitted requests run in submission order
    assert_eq!(fifo.order, vec![0, 1, 2, 3, 4]);

    let sjf = engine_with(&rt, SchedPolicy::Sjf, 2)
        .serve_with(&reqs, &FakeFactory)
        .unwrap();
    // SJF: the preset-shaped request (tiny = 16 residues) is the cheapest
    // and runs first
    assert_eq!(sjf.order.first(), Some(&0));
    let lat = |i: usize| {
        sjf.outcomes[i]
            .placement
            .as_ref()
            .map(|p| p.modeled_latency)
            .unwrap_or(0.0)
    };
    let pos =
        |i: usize| sjf.order.iter().position(|&k| k == i).expect("scheduled");
    for &a in &sjf.order {
        for &b in &sjf.order {
            if lat(a) < lat(b) {
                // shorter job runs earlier unless the starvation guard
                // promoted an older long job past it
                assert!(
                    pos(a) < pos(b) || b < a,
                    "sjf order violated: {} vs {}",
                    sjf.outcomes[a].id,
                    sjf.outcomes[b].id
                );
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn priorities_override_latency_within_policy() {
    let (rt, dir) = stub_runtime("priority");
    let mut reqs = mixed_batch();
    reqs.truncate(4); // preset-short, short-512, long-2048, dist-4096
    reqs[3].priority = 0;
    for r in reqs.iter_mut().take(3) {
        r.priority = 1; // demote everything except the DAP job
    }
    let report = engine_with(&rt, SchedPolicy::Sjf, 1)
        .serve_with(&reqs, &FakeFactory)
        .unwrap();
    // the urgent long job runs first despite SJF
    assert_eq!(report.order.first(), Some(&3));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn engine_drain_survives_backend_failure() {
    // a factory that refuses DAP placements: the failed request reports
    // its error, everything else completes
    struct FlakyFactory;
    impl BackendFactory for FlakyFactory {
        fn make<'a>(
            &'a self,
            req: &InferRequest,
            placement: &Placement,
            rank_threads: usize,
        ) -> Result<Box<dyn InferBackend + 'a>> {
            if matches!(placement.backend, BackendKind::Dap(_)) {
                return Err(Error::msg("no DAP workers available"));
            }
            FakeFactory.make(req, placement, rank_threads)
        }
    }
    let (rt, dir) = stub_runtime("flaky");
    let reqs = mixed_batch();
    let report = engine_with(&rt, SchedPolicy::Fifo, 4)
        .serve_with(&reqs, &FlakyFactory)
        .unwrap();
    assert_eq!(report.completed(), 3); // two DAP jobs fail, one rejected
    for o in &report.outcomes {
        let is_dap = o
            .placement
            .as_ref()
            .map(|p| matches!(p.backend, BackendKind::Dap(_)))
            .unwrap_or(false);
        if is_dap {
            let e = o.output.as_ref().unwrap_err();
            assert!(e.to_string().contains("no DAP workers"), "{e}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------------- artifact-gated

#[test]
fn engine_outputs_match_legacy_paths_bit_for_bit() {
    let Some(rt) = artifact_runtime() else { return };
    let engine = engine_with(&rt, SchedPolicy::Fifo, 1);
    let mut dap2 = InferRequest::new("dap2", "tiny");
    dap2.force = Some(BackendKind::Dap(2));
    let mut chunked = InferRequest::new("chunked", "tiny");
    chunked.force = Some(BackendKind::Chunked);
    let mut naive = InferRequest::new("naive", "tiny");
    naive.naive = true;
    let reqs = vec![InferRequest::new("single", "tiny"), dap2, chunked, naive];
    let report = engine.serve(&reqs).unwrap();

    // legacy invocations, same seed-7 input stream the engine generates
    let params = rt.manifest.load_params("tiny").unwrap();
    let batch = || DataGen::new(ModelConfig::tiny(), 7).next_batch();
    let (m_ref, z_ref) =
        single_device_forward(&rt, "tiny", &params, &batch().msa_tokens, false).unwrap();
    let (m_nv, z_nv) =
        single_device_forward(&rt, "tiny", &params, &batch().msa_tokens, true).unwrap();

    let out = |i: usize| report.outcomes[i].output.as_ref().expect("completed");
    assert_eq!(out(0).0.data(), m_ref.data(), "single m");
    assert_eq!(out(0).1.data(), z_ref.data(), "single z");
    // chunked is a memory schedule, not a numeric change
    assert_eq!(out(2).0.data(), m_ref.data(), "chunked m");
    assert_eq!(out(2).1.data(), z_ref.data(), "chunked z");
    assert_eq!(out(3).0.data(), m_nv.data(), "naive m");
    assert_eq!(out(3).1.data(), z_nv.data(), "naive z");
    // DAP artifacts may not be exported for every degree; when the legacy
    // path runs, the engine must match it bit-for-bit
    if let Ok(co) = DapCoordinator::new(&rt, "tiny", 2, true) {
        let (m_dap, z_dap) = co.model_forward(&params, &batch().msa_tokens).unwrap();
        assert_eq!(out(1).0.data(), m_dap.data(), "dap m");
        assert_eq!(out(1).1.data(), z_dap.data(), "dap z");
    } else {
        assert!(report.outcomes[1].output.is_err());
    }
}

#[test]
fn executed_drain_is_thread_invariant() {
    let Some(rt) = artifact_runtime() else { return };
    let mut dap2 = InferRequest::new("dap2", "tiny");
    dap2.force = Some(BackendKind::Dap(2));
    let reqs = vec![
        InferRequest::new("a", "tiny"),
        dap2,
        InferRequest::new("b", "tiny"),
    ];
    let r1 = engine_with(&rt, SchedPolicy::Sjf, 1).serve(&reqs).unwrap();
    let r4 = engine_with(&rt, SchedPolicy::Sjf, 4).serve(&reqs).unwrap();
    assert_eq!(r1.order, r4.order);
    for (a, b) in r1.outcomes.iter().zip(r4.outcomes.iter()) {
        match (&a.output, &b.output) {
            (Ok((am, az)), Ok((bm, bz))) => {
                assert_eq!(am.data(), bm.data(), "'{}'", a.id);
                assert_eq!(az.data(), bz.data(), "'{}'", a.id);
            }
            (Err(ae), Err(be)) => assert_eq!(ae.to_string(), be.to_string()),
            _ => panic!("disposition of '{}' changed with threads", a.id),
        }
    }
}
