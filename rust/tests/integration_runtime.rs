//! Runtime integration: manifest loading, artifact compile+execute,
//! input validation, fused-vs-naive numerics at block level.
//!
//! Requires `make artifacts` (tiny preset). Tests skip gracefully if the
//! artifacts directory is missing so `cargo test` stays green pre-build.

use fastfold::manifest::Manifest;
use fastfold::rng::Rng;
use fastfold::runtime::Runtime;
use fastfold::tensor::HostTensor;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::new(shape.to_vec(), rng.normal_vec(n, 1.0)).unwrap()
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(rt) = runtime() else { return };
    let man = &rt.manifest;
    assert!(man.artifacts.contains_key("tiny/block_fwd"));
    assert!(man.artifacts.contains_key("tiny/dap2/msa_row_core"));
    // params binary matches recorded total
    let params = man.load_params("tiny").unwrap();
    let total: usize = params.iter().map(|p| p.len()).sum();
    assert_eq!(total, man.params["tiny"].total);
    // config param count matches the closed-form counter
    let cfg = fastfold::config::ModelConfig::tiny();
    assert_eq!(man.params["tiny"].count, cfg.param_count());
}

#[test]
fn manifest_missing_dir_errors() {
    assert!(Manifest::load("/definitely/not/here").is_err());
}

#[test]
fn block_forward_executes_and_matches_naive() {
    let Some(rt) = runtime() else { return };
    let cfg = fastfold::config::ModelConfig::tiny();
    let params = rt.manifest.load_params("tiny").unwrap();
    let idx = rt.manifest.block_leaf_indices("tiny", 0).unwrap();
    let mut rng = Rng::new(7);
    let m = rand_tensor(&mut rng, &[cfg.n_seq, cfg.n_res, cfg.d_msa]);
    let z = rand_tensor(&mut rng, &[cfg.n_res, cfg.n_res, cfg.d_pair]);

    let mut args: Vec<HostTensor> = idx.iter().map(|&i| params[i].clone()).collect();
    args.push(m.clone());
    args.push(z.clone());

    let fused = rt.load("tiny/block_fwd").unwrap().run_f32(&args).unwrap();
    let naive = rt.load("tiny/block_fwd_naive").unwrap().run_f32(&args).unwrap();
    assert_eq!(fused.len(), 2);
    assert_eq!(fused[0].shape, m.shape);
    assert_eq!(fused[1].shape, z.shape);
    // §V.D: fused kernels change instruction order, not math
    assert!(fused[0].max_abs_diff(&naive[0]) < 1e-3, "m diff");
    assert!(fused[1].max_abs_diff(&naive[1]) < 1e-3, "z diff");
    // and the block actually transforms the input
    assert!(fused[0].max_abs_diff(&m) > 1e-3);
}

#[test]
fn executable_rejects_bad_inputs() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("tiny/block_fwd").unwrap();
    // wrong arity
    assert!(exe.run_f32(&[HostTensor::zeros(&[2, 2])]).is_err());
    // right arity, wrong shapes
    let n = exe.spec.inputs.len();
    let bad: Vec<HostTensor> = (0..n).map(|_| HostTensor::zeros(&[3])).collect();
    assert!(exe.run_f32(&bad).is_err());
}

#[test]
fn executable_cache_hits() {
    let Some(rt) = runtime() else { return };
    let a = rt.load("tiny/heads").unwrap();
    let before = rt.cached();
    let b = rt.load("tiny/heads").unwrap();
    assert_eq!(rt.cached(), before);
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}

#[test]
fn model_fwd_deterministic() {
    let Some(rt) = runtime() else { return };
    let params = rt.manifest.load_params("tiny").unwrap();
    let cfg = fastfold::config::ModelConfig::tiny();
    let mut gen = fastfold::train::DataGen::new(cfg, 3);
    let batch = gen.next_batch();
    let run = || {
        fastfold::inference::single_device_forward(
            &rt, "tiny", &params, &batch.msa_tokens, false,
        )
        .unwrap()
    };
    let (m1, z1) = run();
    let (m2, z2) = run();
    assert_eq!(m1.max_abs_diff(&m2), 0.0);
    assert_eq!(z1.max_abs_diff(&z2), 0.0);
}
