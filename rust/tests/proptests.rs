//! Property-based tests (hand-rolled generator loop over the deterministic
//! Rng — proptest is unavailable offline): invariants of the tensor ops,
//! collectives, ring reduction, JSON codec, and the schedule.

use fastfold::comm::ring::ring_all_reduce;
use fastfold::comm::Collectives;
use fastfold::json::Json;
use fastfold::rng::Rng;
use fastfold::tensor::HostTensor;

const CASES: usize = 60;

fn rand_shape(rng: &mut Rng, maxd: usize) -> Vec<usize> {
    let nd = 1 + rng.below(3);
    (0..nd).map(|_| 1 + rng.below(maxd)).collect()
}

#[test]
fn prop_split_concat_identity() {
    let mut rng = Rng::new(100);
    for case in 0..CASES {
        let mut shape = rand_shape(&mut rng, 6);
        let axis = rng.below(shape.len());
        let n = 1 + rng.below(4);
        shape[axis] *= n; // ensure divisibility
        let numel: usize = shape.iter().product();
        let t = HostTensor::new(shape.clone(), rng.normal_vec(numel, 1.0)).unwrap();
        let parts = t.split_axis(axis, n).unwrap();
        assert_eq!(parts.len(), n);
        let back = HostTensor::concat(&parts, axis).unwrap();
        assert_eq!(back, t, "case {case} shape {shape:?} axis {axis} n {n}");
    }
}

#[test]
fn prop_all_to_all_roundtrip() {
    let mut rng = Rng::new(101);
    for case in 0..CASES {
        let n = 2 + rng.below(3);
        let a = n * (1 + rng.below(3));
        let b = n * (1 + rng.below(3));
        let c = 1 + rng.below(5);
        let full = HostTensor::new(vec![a, b, c], rng.normal_vec(a * b * c, 1.0)).unwrap();
        let comm = Collectives::new(n);
        let shards = full.split_axis(0, n).unwrap();
        let fwd = comm.all_to_all(&shards, 1, 0).unwrap();
        let back = comm.all_to_all(&fwd, 0, 1).unwrap();
        for (x, y) in back.iter().zip(shards.iter()) {
            assert_eq!(x, y, "case {case} n={n} dims=({a},{b},{c})");
        }
    }
}

#[test]
fn prop_gather_scatter_duality() {
    // reduce_scatter(all_gather(x)) == n * x  (the vjp pair used by the
    // DAP backward tape)
    let mut rng = Rng::new(102);
    for _ in 0..CASES {
        let n = 2 + rng.below(3);
        let rows = 1 + rng.below(4);
        let cols = 1 + rng.below(6);
        let shards: Vec<HostTensor> = (0..n)
            .map(|_| HostTensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 1.0)).unwrap())
            .collect();
        let comm = Collectives::new(n);
        let full = comm.all_gather(&shards, 0).unwrap();
        let back = comm.reduce_scatter(&full, 0).unwrap();
        for (r, (got, want)) in back.iter().zip(shards.iter()).enumerate() {
            let mut scaled = want.clone();
            scaled.scale(n as f32);
            assert!(got.max_abs_diff(&scaled) < 1e-4 * n as f32, "rank {r}");
        }
    }
}

#[test]
fn prop_ring_all_reduce_matches_sum() {
    let mut rng = Rng::new(103);
    for _ in 0..CASES {
        let n = 1 + rng.below(8);
        let len = 1 + rng.below(200);
        let ranks: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(len, 1.0)).collect();
        let want: Vec<f32> = (0..len)
            .map(|i| ranks.iter().map(|r| r[i]).sum::<f32>())
            .collect();
        let (got, _) = ring_all_reduce(ranks).unwrap();
        for r in &got {
            for (a, b) in r.iter().zip(want.iter()) {
                assert!((a - b).abs() < 1e-3, "n={n} len={len}");
            }
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    let mut rng = Rng::new(104);
    for _ in 0..CASES {
        let v = gen_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v, "text: {text}");
    }
}

fn gen_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.bernoulli(0.5)),
        2 => Json::Num((rng.normal() * 100.0).round()),
        3 => {
            let strs = ["hello", "wörld", "a\"b", "tab\there", "line\nbreak", ""];
            Json::Str(strs[rng.below(strs.len())].to_string())
        }
        4 => Json::Arr((0..rng.below(4)).map(|_| gen_json(rng, depth - 1)).collect()),
        _ => {
            let mut m = std::collections::BTreeMap::new();
            for i in 0..rng.below(4) {
                m.insert(format!("k{i}"), gen_json(rng, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn prop_transpose01_involution() {
    let mut rng = Rng::new(105);
    for _ in 0..CASES {
        let a = 1 + rng.below(6);
        let b = 1 + rng.below(6);
        let c = 1 + rng.below(4);
        let t = HostTensor::new(vec![a, b, c], rng.normal_vec(a * b * c, 1.0)).unwrap();
        assert_eq!(t.transpose01().unwrap().transpose01().unwrap(), t);
    }
}

#[test]
fn prop_memory_model_monotone() {
    // peak memory is monotone in sequence length and antitone in dap degree
    use fastfold::config::ModelConfig;
    use fastfold::perfmodel::MemoryModel;
    let m = MemoryModel::default();
    let mut rng = Rng::new(106);
    for _ in 0..CASES {
        let r1 = 256 + 64 * rng.below(30);
        let r2 = r1 + 64 * (1 + rng.below(10));
        let dap = 1 << rng.below(4);
        let p1 = m.inference_peak(&ModelConfig::inference(r1), dap, 1);
        let p2 = m.inference_peak(&ModelConfig::inference(r2), dap, 1);
        assert!(p2 >= p1, "r {r1}->{r2} dap {dap}");
        let p_more = m.inference_peak(&ModelConfig::inference(r1), dap * 2, 1);
        assert!(p_more <= p1, "dap {dap}->{} at r={r1}", dap * 2);
    }
}

#[test]
fn prop_autochunk_fits_when_feasible() {
    // every plan the planner returns fits capacity; every refusal is a
    // sim-OOM verdict, never a silent failure
    use fastfold::config::ModelConfig;
    use fastfold::inference::autochunk;
    use fastfold::perfmodel::{GpuSpec, MemoryModel};
    let mem = MemoryModel::default();
    let gpu = GpuSpec::a100_40g();
    let mut rng = Rng::new(107);
    for _ in 0..CASES {
        let r = 256 + 64 * rng.below(60);
        let dap = 1usize << rng.below(4);
        match autochunk::plan(&ModelConfig::inference(r), &mem, &gpu, dap) {
            Ok(p) => {
                assert!(p.fits(), "r={r} dap={dap}: {}", p.summary());
                assert!(p.peak_bytes <= p.unchunked_peak_bytes * (1.0 + 1e-12));
                assert!(p.latency_factor >= 1.0);
                // every strategy respects its module's chunk axis
                for s in &p.modules {
                    let axis = s.module.chunk_axis_len(
                        &ModelConfig::inference(r), dap);
                    assert!(s.chunks >= 1 && s.chunks <= axis.max(1));
                }
            }
            Err(e) => assert!(
                matches!(e, fastfold::Error::SimOom { .. }),
                "r={r} dap={dap}: {e}"
            ),
        }
    }
}

#[test]
fn prop_autochunk_monotone_in_length() {
    // per-module chunk counts never decrease as sequence length grows:
    // longer sequences can only need equal-or-deeper chunking
    use fastfold::config::ModelConfig;
    use fastfold::inference::autochunk;
    use fastfold::perfmodel::memory::BlockModule;
    use fastfold::perfmodel::{GpuSpec, MemoryModel};
    let mem = MemoryModel::default();
    let gpu = GpuSpec::a100_40g();
    let mut rng = Rng::new(108);
    for _ in 0..CASES {
        // both lengths inside the single-device feasible band (≤ 2944)
        let r1 = 256 + 64 * rng.below(30);
        let r2 = (r1 + 64 * (1 + rng.below(12))).min(2944);
        let p1 = autochunk::plan(&ModelConfig::inference(r1), &mem, &gpu, 1)
            .unwrap_or_else(|e| panic!("r1={r1}: {e}"));
        let p2 = autochunk::plan(&ModelConfig::inference(r2), &mem, &gpu, 1)
            .unwrap_or_else(|e| panic!("r2={r2}: {e}"));
        for m in BlockModule::ALL {
            assert!(
                p2.chunks_for(m) >= p1.chunks_for(m),
                "{}: r {r1}->{r2} chunks {} -> {}",
                m.name(),
                p1.chunks_for(m),
                p2.chunks_for(m)
            );
        }
    }
}

#[test]
fn prop_autochunk_agrees_with_legacy_pow2() {
    // (a) planner feasibility matches the legacy pow2 heuristic exactly;
    // (b) wherever legacy finds a plan, the planner's MSA-row strategy
    //     (the one axis both can chunk) streams at most as much transient
    //     as the legacy power-of-two choice — never a regression
    use fastfold::config::ModelConfig;
    use fastfold::inference::{autochunk, chunking};
    use fastfold::perfmodel::memory::BlockModule;
    use fastfold::perfmodel::{GpuSpec, MemoryModel};
    let mem = MemoryModel::default();
    let gpu = GpuSpec::a100_40g();
    let mut rng = Rng::new(109);
    for _ in 0..CASES {
        let r = 256 + 64 * rng.below(60);
        let cfg = ModelConfig::inference(r);
        let legacy = chunking::plan_chunks(&cfg, &mem, &gpu);
        let full = autochunk::plan(&cfg, &mem, &gpu, 1);
        assert_eq!(
            legacy.is_some(),
            full.is_ok(),
            "r={r}: legacy {legacy:?} vs planner {:?}",
            full.as_ref().err().map(|e| e.to_string())
        );
        if let (Some(l), Ok(p)) = (&legacy, &full) {
            let legacy_msa = mem.elem_bytes
                * mem.module_transient_elems(
                    &cfg,
                    BlockModule::MsaRowAttn,
                    1,
                    l.chunks,
                );
            let new_msa = p
                .modules
                .iter()
                .find(|s| s.module == BlockModule::MsaRowAttn)
                .unwrap();
            assert!(
                new_msa.transient_bytes <= legacy_msa + 1.0,
                "r={r}: planner {} (c={}) vs legacy {} (c={})",
                new_msa.transient_bytes,
                new_msa.chunks,
                legacy_msa,
                l.chunks
            );
        }
    }
}

#[test]
fn prop_scaling_model_sane() {
    // step time decreases (or stays) with more DAP ranks; efficiency <= 1
    use fastfold::config::ModelConfig;
    use fastfold::perfmodel::gpu::ImplProfile;
    use fastfold::perfmodel::scaling::{MpMethod, ScalingModel};
    let m = ScalingModel::default();
    let p = ImplProfile::fastfold();
    for cfg in [ModelConfig::initial_training(), ModelConfig::finetune()] {
        let mut prev = f64::INFINITY;
        for n in [1usize, 2, 4, 8] {
            let t = m.train_step(&cfg, &p, MpMethod::Dap, n, true).total();
            assert!(t > 0.0);
            assert!(t <= prev * 1.001, "{}: t({n})={t} prev={prev}", cfg.name);
            let t1 = m.train_step(&cfg, &p, MpMethod::Dap, 1, true).total();
            assert!(t1 / (n as f64 * t) <= 1.02);
            prev = t;
        }
    }
}
