//! View-based host data plane — equivalence property suite.
//!
//! The Arc-backed view rewrite of `HostTensor` must be observationally
//! identical to the old copying implementation: every op yields the same
//! elements in the same order, and no view can leak a mutation into
//! another view's data. The copying reference implementations
//! (`slice_axis_copy`, `concat_copy` — the pre-view algorithms, kept on
//! the type) are the oracles.
//!
//! Bit-for-bit DAP executor equivalence at dap ∈ {2,4,8} lives in
//! `threaded_executor.rs`; serve/train thread-budget invariance in
//! `serve_engine.rs` / `hybrid_trainer.rs` — all three suites now drive
//! the view-based plane end to end.

use fastfold::comm::Collectives;
use fastfold::rng::Rng;
use fastfold::tensor::HostTensor;

const CASES: usize = 80;

fn rand_shape(rng: &mut Rng, maxd: usize) -> Vec<usize> {
    let nd = 1 + rng.below(3);
    (0..nd).map(|_| 1 + rng.below(maxd)).collect()
}

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::new(shape.to_vec(), rng.normal_vec(n, 1.0)).unwrap()
}

#[test]
fn prop_slice_axis_matches_copy_reference() {
    let mut rng = Rng::new(300);
    for case in 0..CASES {
        let shape = rand_shape(&mut rng, 7);
        let t = rand_tensor(&mut rng, &shape);
        let axis = rng.below(shape.len());
        let d = shape[axis];
        let len = 1 + rng.below(d);
        let start = rng.below(d - len + 1);
        let view = t.slice_axis(axis, start, len).unwrap();
        let copy = t.slice_axis_copy(axis, start, len).unwrap();
        assert_eq!(view.shape, copy.shape, "case {case}");
        assert_eq!(view.data(), copy.data(), "case {case} shape {shape:?} axis {axis}");
        // bit-for-bit, not just PartialEq
        for (a, b) in view.data().iter().zip(copy.data().iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

#[test]
fn prop_split_concat_matches_copy_reference() {
    let mut rng = Rng::new(301);
    for case in 0..CASES {
        let mut shape = rand_shape(&mut rng, 5);
        let axis = rng.below(shape.len());
        let n = 1 + rng.below(4);
        shape[axis] *= n;
        let t = rand_tensor(&mut rng, &shape);
        let parts = t.split_axis(axis, n).unwrap();
        // view-based concat == copying concat == the original tensor
        let back = HostTensor::concat(&parts, axis).unwrap();
        let back_copy = HostTensor::concat_copy(&parts, axis).unwrap();
        assert_eq!(back, t, "case {case}");
        assert_eq!(back_copy, t, "case {case}");
        assert_eq!(back.data(), back_copy.data());
    }
}

#[test]
fn prop_concat_of_unrelated_tensors_matches_reference() {
    // parts that are NOT adjacent views (fresh tensors) must take the
    // gather path and still agree with the reference
    let mut rng = Rng::new(302);
    for case in 0..CASES {
        let mut shape = rand_shape(&mut rng, 5);
        let axis = rng.below(shape.len());
        let n = 2 + rng.below(3);
        let parts: Vec<HostTensor> = (0..n)
            .map(|_| {
                shape[axis] = 1 + rng.below(4);
                rand_tensor(&mut rng, &shape)
            })
            .collect();
        let a = HostTensor::concat(&parts, axis).unwrap();
        let b = HostTensor::concat_copy(&parts, axis).unwrap();
        assert_eq!(a, b, "case {case} axis {axis}");
    }
}

#[test]
fn prop_transpose01_involution_and_reference_values() {
    let mut rng = Rng::new(303);
    for _ in 0..CASES {
        let a = 1 + rng.below(6);
        let b = 1 + rng.below(6);
        let c = 1 + rng.below(4);
        let t = rand_tensor(&mut rng, &[a, b, c]);
        let tt = t.transpose01().unwrap();
        assert_eq!(tt.transpose01().unwrap(), t);
        // element-for-element against the index formula
        for i in 0..a {
            for j in 0..b {
                for k in 0..c {
                    assert_eq!(
                        tt.data()[(j * a + i) * c + k].to_bits(),
                        t.data()[(i * b + j) * c + k].to_bits()
                    );
                }
            }
        }
    }
}

#[test]
fn prop_views_never_leak_mutations() {
    // mutate every shard of a split through data_mut and verify the
    // parent and sibling shards are untouched
    let mut rng = Rng::new(304);
    for _ in 0..CASES / 2 {
        let n = 2 + rng.below(3);
        let rows = n * (1 + rng.below(4));
        let cols = 1 + rng.below(6);
        let t = rand_tensor(&mut rng, &[rows, cols]);
        let snapshot = t.to_vec();
        let mut parts = t.split_axis(0, n).unwrap();
        let originals: Vec<Vec<f32>> = parts.iter().map(|p| p.to_vec()).collect();
        for (i, p) in parts.iter_mut().enumerate() {
            let d = p.data_mut();
            d[0] += (i + 1) as f32;
        }
        assert_eq!(t.to_vec(), snapshot, "parent mutated through a view");
        for (i, (p, orig)) in parts.iter().zip(originals.iter()).enumerate() {
            assert_eq!(p.data()[0], orig[0] + (i + 1) as f32);
            assert_eq!(&p.data()[1..], &orig[1..], "shard {i} tail changed");
        }
    }
}

#[test]
fn prop_add_assign_scale_match_scalar_reference() {
    let mut rng = Rng::new(305);
    for _ in 0..CASES {
        let shape = rand_shape(&mut rng, 6);
        let a = rand_tensor(&mut rng, &shape);
        let b = rand_tensor(&mut rng, &shape);
        let s = rng.normal() as f32;
        // reference on plain vectors
        let mut want: Vec<f32> = a.to_vec();
        for (w, &bv) in want.iter_mut().zip(b.data()) {
            *w += bv;
        }
        for w in want.iter_mut() {
            *w *= s;
        }
        // kernel path, run through a shared view to exercise CoW
        let mut got = a.clone();
        got.add_assign(&b).unwrap();
        got.scale(s);
        for (x, y) in got.data().iter().zip(want.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}

#[test]
fn prop_collectives_on_views_match_collectives_on_copies() {
    // the DAP data plane in miniature: shard (views) vs shard (copies)
    // through every collective, bit-for-bit, at group sizes 2/4/8
    let mut rng = Rng::new(306);
    for &n in &[2usize, 4, 8] {
        for _ in 0..10 {
            // rows = n² · k so the reduce_scatter of an [rows/n, cols]
            // shard can itself split n ways along axis 0
            let rows = n * n * (1 + rng.below(2));
            let cols = n * (1 + rng.below(3));
            let full = rand_tensor(&mut rng, &[rows, cols]);
            let views = full.split_axis(0, n).unwrap();
            let copies: Vec<HostTensor> = (0..n)
                .map(|i| full.slice_axis_copy(0, i * (rows / n), rows / n).unwrap())
                .collect();
            let cv = Collectives::new(n);
            let cc = Collectives::new(n);
            let pairs = [
                (cv.all_gather(&views, 0).unwrap(), cc.all_gather(&copies, 0).unwrap()),
                (
                    cv.all_to_all(&views, 1, 0).unwrap(),
                    cc.all_to_all(&copies, 1, 0).unwrap(),
                ),
                (cv.all_reduce(&views).unwrap(), cc.all_reduce(&copies).unwrap()),
                (
                    cv.reduce_scatter(&views, 0).unwrap(),
                    cc.reduce_scatter(&copies, 0).unwrap(),
                ),
            ];
            for (got, want) in pairs {
                assert_eq!(got.len(), want.len());
                for (g, w) in got.iter().zip(want.iter()) {
                    assert_eq!(g.shape, w.shape, "n={n}");
                    for (x, y) in g.data().iter().zip(w.data().iter()) {
                        assert_eq!(x.to_bits(), y.to_bits(), "n={n}");
                    }
                }
            }
        }
    }
}

#[test]
fn shard_move_view_path_is_metadata_only() {
    // the tentpole contract: split along the DAP axis shares storage and
    // unshard reassembles the parent without copying
    let t = HostTensor::new(vec![8, 16], (0..128).map(|i| i as f32).collect()).unwrap();
    let parts = t.split_axis(0, 4).unwrap();
    assert!(parts.iter().all(|p| p.shares_storage(&t)));
    let back = HostTensor::concat(&parts, 0).unwrap();
    assert!(back.shares_storage(&t));
    assert_eq!(back, t);
}
