//! Static-verifier property suite: the abstract interpreter in
//! `fastfold::analysis` must agree with the runtime hazard detectors in
//! `fastfold::dap::executor` on every schedule — valid or mutated.
//!
//! Three layers:
//!
//! 1. **Regression** — the exact stale-read repro the runtime detectors
//!    were built around is now rejected *statically*, before anything
//!    runs, with an actionable diagnostic.
//! 2. **Fuzz (valid)** — randomized hazard-free schedules at
//!    dap ∈ {2,4,8}: the verifier proves them clean AND the threaded
//!    executor runs them to completion.
//! 3. **Fuzz (mutated)** — each hazard class injected into valid
//!    schedules: the verifier refutes them AND the runtime detectors
//!    error. Static verdict ⇔ dynamic outcome, schedule by schedule.

use fastfold::analysis::{self, Hazard, Program, VerifyReport};
use fastfold::comm::Collectives;
use fastfold::dap::executor::{run_schedule, MeasuredComm, State};
use fastfold::dap::{CommCost, SegmentRunner, Timeline};
use fastfold::manifest::ScheduleOp;
use fastfold::rng::Rng;
use fastfold::tensor::HostTensor;
use fastfold::Result;
use std::sync::Mutex;

/// Deterministic pure-host segment runner (no PJRT): `scale` is
/// 0.5x + 1 elementwise.
struct FakeRunner;

impl SegmentRunner for FakeRunner {
    fn run_segment(
        &self,
        seg: &str,
        _rank: usize,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        match seg {
            "scale" => Ok(vec![HostTensor::new(
                inputs[0].shape.clone(),
                inputs[0].data().iter().map(|&x| 0.5 * x + 1.0).collect(),
            )?]),
            other => {
                Err(fastfold::Error::Schedule(format!("fake: no segment '{other}'")))
            }
        }
    }
}

/// Block-entry state: m (16×4) and z (16×8), each split along axis 0.
fn entry_state(rng: &mut Rng, n: usize) -> State {
    let m = HostTensor::new(vec![16, 4], rng.normal_vec(64, 1.0)).unwrap();
    let z = HostTensor::new(vec![16, 8], rng.normal_vec(128, 1.0)).unwrap();
    let mut state = State::new();
    state.insert("m".into(), m.split_axis(0, n).unwrap());
    state.insert("z".into(), z.split_axis(0, n).unwrap());
    state
}

/// Run a schedule on the real threaded executor (the dynamic oracle).
fn run_dynamic(sched: &[ScheduleOp], n: usize, seed: u64) -> Result<()> {
    let mut rng = Rng::new(seed);
    let mut state = entry_state(&mut rng, n);
    let comm = Collectives::new(n);
    let timeline = Mutex::new(Timeline::new(n, CommCost::cpu_calibrated(), true));
    let measured = Mutex::new(MeasuredComm::default());
    run_schedule(
        sched, n, 2, &FakeRunner, &comm, &timeline, &measured, None, &mut state,
        None,
    )
}

/// Lift a schedule into the effect IR with the harness entry shapes and
/// run the static verifier.
fn run_static(sched: &[ScheduleOp], n: usize) -> VerifyReport {
    let entry = [
        ("m", Some(vec![16 / n, 4])),
        ("z", Some(vec![16 / n, 8])),
    ];
    analysis::verify(&Program::from_schedule("fuzz", sched, n, &entry))
}

fn has(report: &VerifyReport, hazard: Hazard) -> bool {
    report.diagnostics.iter().any(|d| d.hazard == hazard)
}

// ------------------------------------------------------------ generator

/// One async collective inside a generated schedule, with the indices the
/// mutation suite needs to corrupt it.
struct AsyncSite {
    trigger_idx: usize,
    wait_idx: usize,
    id: String,
    dest: String,
}

fn exec(input: &str, output: &str) -> ScheduleOp {
    ScheduleOp::Exec {
        seg: "scale".into(),
        inputs: vec![input.into()],
        outputs: vec![output.into()],
    }
}

fn gather(input: &str, output: &str, id: &str) -> ScheduleOp {
    ScheduleOp::Gather {
        input: input.into(),
        output: output.into(),
        axis: 0,
        id: Some(id.into()),
    }
}

/// Generate a random hazard-free schedule: async gathers to fresh slots,
/// execs over joined slots, every collective joined before the end.
/// Invariant maintained: no op ever reads or writes an in-flight
/// destination, and only `m`/`z`/joined/exec-written slots are read.
fn fuzz_valid(rng: &mut Rng, len: usize) -> (Vec<ScheduleOp>, Vec<AsyncSite>) {
    let mut sched: Vec<ScheduleOp> = Vec::new();
    let mut sites: Vec<AsyncSite> = Vec::new();
    let mut safe: Vec<String> = vec!["m".into(), "z".into()];
    // (id, dest, trigger_idx) for collectives triggered but not yet joined
    let mut inflight: Vec<(String, String, usize)> = Vec::new();
    let mut next = 0usize;

    for _ in 0..len {
        let choice = rng.below(3);
        if choice == 0 && inflight.len() < 3 {
            // trigger an async gather into a fresh slot
            let src = safe[rng.below(safe.len())].clone();
            let id = format!("h{next}");
            let dest = format!("g{next}");
            next += 1;
            inflight.push((id.clone(), dest.clone(), sched.len()));
            sched.push(gather(&src, &dest, &id));
        } else if choice == 1 && !inflight.is_empty() {
            // join the oldest in-flight collective; its dest becomes safe
            let (id, dest, trigger_idx) = inflight.remove(0);
            sites.push(AsyncSite {
                trigger_idx,
                wait_idx: sched.len(),
                id: id.clone(),
                dest: dest.clone(),
            });
            sched.push(ScheduleOp::Wait { id });
            safe.push(dest);
        } else {
            // exec a safe slot into a fresh one (never an in-flight dest)
            let src = safe[rng.below(safe.len())].clone();
            let dest = format!("e{next}");
            next += 1;
            sched.push(exec(&src, &dest));
            safe.push(dest);
        }
    }
    // drain: join everything still in flight
    for (id, dest, trigger_idx) in inflight {
        sites.push(AsyncSite {
            trigger_idx,
            wait_idx: sched.len(),
            id: id.clone(),
            dest,
        });
        sched.push(ScheduleOp::Wait { id });
    }
    (sched, sites)
}

// ----------------------------------------------------------- regression

#[test]
fn pr2_stale_read_repro_is_rejected_statically_before_it_runs() {
    // the exact schedule the runtime detectors were built around: an Exec
    // consuming `m` while an async gather is still writing it
    let sched = vec![gather("m", "m", "h1"), exec("m", "m"), ScheduleOp::Wait {
        id: "h1".into(),
    }];
    let n = 2;

    let report = run_static(&sched, n);
    assert!(has(&report, Hazard::StaleRead), "{:?}", report.diagnostics);
    let d = report
        .diagnostics
        .iter()
        .find(|d| d.hazard == Hazard::StaleRead)
        .unwrap();
    assert_eq!(d.buffer, "m");
    assert_eq!(d.step, 1, "hazard manifests at the Exec step");
    assert!(!d.fix.is_empty(), "diagnostic must suggest a schedule edit");
    let gate = report.gate().unwrap_err().to_string();
    assert!(gate.contains("stale-read"), "{gate}");

    // the dynamic oracle agrees — but only after actually running
    let err = run_dynamic(&sched, n, 9).unwrap_err().to_string();
    assert!(err.contains("stale read"), "{err}");
}

#[test]
fn canonical_program_is_proven_hazard_free_fwd_and_bwd() {
    let cfg = fastfold::config::ModelConfig::tiny();
    for n in [1usize, 2, 4, 8] {
        let (fwd, bwd) = analysis::verify_canonical("tiny", &cfg, n);
        assert!(
            fwd.is_hazard_free(),
            "forward dap={n}: {:?}",
            fwd.diagnostics
        );
        assert!(
            bwd.is_hazard_free(),
            "backward dap={n}: {:?}",
            bwd.diagnostics
        );
        assert!(fwd.steps > 0 && bwd.steps > 0);
        let json = fwd.to_json().to_string();
        assert!(json.contains("\"hazard_free\":true"), "{json}");
    }
}

// ---------------------------------------------------------- fuzz: valid

#[test]
fn fuzz_valid_schedules_verify_clean_and_run_clean() {
    for n in [2usize, 4, 8] {
        for case in 0..20u64 {
            let mut rng = Rng::new(4000 + case);
            let len = 8 + rng.below(8);
            let (sched, _) = fuzz_valid(&mut rng, len);
            let report = run_static(&sched, n);
            assert!(
                report.is_hazard_free(),
                "n={n} case={case}: static refutation of a valid schedule: \
                 {:?}\nschedule: {sched:?}",
                report.diagnostics
            );
            let ran = run_dynamic(&sched, n, 5000 + case);
            assert!(
                ran.is_ok(),
                "n={n} case={case}: runtime rejected a statically-clean \
                 schedule: {:?}",
                ran.err()
            );
        }
    }
}

// -------------------------------------------------------- fuzz: mutated

/// The injectable hazard classes, one mutation each.
#[derive(Clone, Copy, Debug)]
enum Mutation {
    ReadDestBeforeWait,
    WriteDestBeforeWait,
    DropWait,
    DuplicateWait,
    UnknownWait,
    RetriggerInflightId,
}

const MUTATIONS: [Mutation; 6] = [
    Mutation::ReadDestBeforeWait,
    Mutation::WriteDestBeforeWait,
    Mutation::DropWait,
    Mutation::DuplicateWait,
    Mutation::UnknownWait,
    Mutation::RetriggerInflightId,
];

/// Corrupt a valid schedule at one async site. Returns the mutated
/// schedule and the hazard class the verifier must report.
fn mutate(
    sched: &[ScheduleOp],
    site: &AsyncSite,
    m: Mutation,
) -> (Vec<ScheduleOp>, Hazard) {
    let mut out = sched.to_vec();
    match m {
        Mutation::ReadDestBeforeWait => {
            out.insert(site.wait_idx, exec(&site.dest, "mut_out"));
            (out, Hazard::StaleRead)
        }
        Mutation::WriteDestBeforeWait => {
            out.insert(site.wait_idx, exec("m", &site.dest));
            (out, Hazard::WriteAfterWrite)
        }
        Mutation::DropWait => {
            out.remove(site.wait_idx);
            (out, Hazard::UnjoinedAtEnd)
        }
        Mutation::DuplicateWait => {
            out.insert(site.wait_idx + 1, ScheduleOp::Wait { id: site.id.clone() });
            (out, Hazard::DoubleWait)
        }
        Mutation::UnknownWait => {
            out.push(ScheduleOp::Wait { id: "never-triggered".into() });
            (out, Hazard::UnknownWait)
        }
        Mutation::RetriggerInflightId => {
            out.insert(site.wait_idx, gather("z", "mut_dup", &site.id));
            (out, Hazard::IdReuse)
        }
    }
}

#[test]
fn fuzz_mutated_schedules_are_refuted_statically_and_dynamically() {
    for n in [2usize, 4] {
        for case in 0..10u64 {
            let mut rng = Rng::new(7000 + case);
            let (sched, sites) = fuzz_valid(&mut rng, 10);
            if sites.is_empty() {
                continue; // no async site to corrupt in this draw
            }
            for m in MUTATIONS {
                let site = &sites[rng.below(sites.len())];
                assert!(
                    site.trigger_idx < site.wait_idx,
                    "generator invariant: trigger precedes join"
                );
                let (bad, want) = mutate(&sched, site, m);

                let report = run_static(&bad, n);
                assert!(
                    has(&report, want),
                    "n={n} case={case} {m:?}: verifier missed {want:?}: \
                     {:?}\nschedule: {bad:?}",
                    report.diagnostics
                );
                // every diagnostic is actionable: step, buffer, fix
                for d in &report.diagnostics {
                    assert!(d.step < bad.len() + 1);
                    assert!(!d.buffer.is_empty() && !d.fix.is_empty());
                }

                let ran = run_dynamic(&bad, n, 8000 + case);
                assert!(
                    ran.is_err(),
                    "n={n} case={case} {m:?}: runtime accepted a schedule \
                     the verifier refuted\nschedule: {bad:?}"
                );
            }
        }
    }
}

/// The headline equivalence property, stated directly: over every
/// schedule this suite generates — valid and mutated — the static
/// verdict and the dynamic outcome are the same boolean.
#[test]
fn static_verdict_matches_dynamic_outcome() {
    let n = 4;
    let mut schedules: Vec<Vec<ScheduleOp>> = Vec::new();
    for case in 0..10u64 {
        let mut rng = Rng::new(9000 + case);
        let (sched, sites) = fuzz_valid(&mut rng, 10);
        if let Some(site) = sites.first() {
            for m in MUTATIONS {
                schedules.push(mutate(&sched, site, m).0);
            }
        }
        schedules.push(sched);
    }
    for (i, sched) in schedules.iter().enumerate() {
        let statically_clean = run_static(sched, n).is_hazard_free();
        let dynamically_clean = run_dynamic(sched, n, 100 + i as u64).is_ok();
        assert_eq!(
            statically_clean, dynamically_clean,
            "verdict split on schedule {i}: static={statically_clean} \
             dynamic={dynamically_clean}\nschedule: {sched:?}"
        );
    }
}
