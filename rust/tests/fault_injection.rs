//! Fault-injection suite — the tentpole acceptance tests, artifact-free
//! over the [`SyntheticBackend`]: a seeded schedule of transients and a
//! permanent rank crash is injected into a training run, and the run
//! must complete through retry-with-backoff, CRC retransmit, and
//! checkpoint rollback + DP shrink — converging **bit-for-bit** to the
//! fault-free twin at matched effective batch. The recovery ledger
//! accounts for every absorbed event, the heartbeat executor fails fast
//! on a dead rank, and an unrecoverable crash (no checkpoint plane)
//! surfaces a structured config error instead of hanging or panicking.

use fastfold::config::{ModelConfig, TrainConfig};
use fastfold::faults::{FaultEvent, FaultKind, FaultSchedule, Heartbeats};
use fastfold::train::{ParallelPlan, SyntheticBackend, TrainBackend, Trainer};

fn quick_cfg(steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        lr: 2e-3,
        warmup_steps: 2,
        log_every: 10_000,
        checkpoint_every: 10_000,
        seed: 5,
        ..TrainConfig::default()
    }
}

/// A synthetic-backend trainer over the tiny preset (the
/// `hybrid_trainer.rs` harness, reused under chaos).
fn mk(dp: usize, dap: usize, accum: usize, cfg: TrainConfig) -> Trainer<'static> {
    let model_cfg = ModelConfig::tiny();
    let params = SyntheticBackend::init_params(&model_cfg);
    let backend: Box<dyn TrainBackend> = Box::new(SyntheticBackend::new(dap));
    Trainer::with_backend(
        "tiny",
        model_cfg,
        params,
        backend,
        ParallelPlan::new(dp, dap, accum),
        cfg,
    )
    .unwrap()
}

fn assert_same_state(a: &Trainer, b: &Trainer, what: &str) {
    assert_eq!(a.step, b.step, "{what}: step");
    assert_eq!(a.params.len(), b.params.len(), "{what}: leaf count");
    for (i, (x, y)) in a.params.iter().zip(b.params.iter()).enumerate() {
        assert_eq!(x, y, "{what}: param leaf {i}");
    }
    for (i, (x, y)) in a.m.iter().zip(b.m.iter()).enumerate() {
        assert_eq!(x, y, "{what}: adam m leaf {i}");
    }
    for (i, (x, y)) in a.v.iter().zip(b.v.iter()).enumerate() {
        assert_eq!(x, y, "{what}: adam v leaf {i}");
    }
    assert_eq!(a.params_crc32(), b.params_crc32(), "{what}: param crc");
}

fn tempdir(tag: &str) -> String {
    let dir = std::env::temp_dir()
        .join(format!("ff_faults_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir.to_str().unwrap().to_string()
}

#[test]
fn faulted_run_converges_bitwise_to_fault_free() {
    // the acceptance schedule: two transient OOMs, one comm stall, one
    // corrupted payload, one straggler, and a permanent crash of rank 1
    // — the run must roll back to the last V2 checkpoint, shrink dp 4->2
    // at constant effective batch, re-run the lost step, and finish with
    // exactly the fault-free parameters
    let dir = tempdir("acceptance");
    let mut cfg = quick_cfg(8);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir.clone());

    let mut clean = mk(4, 1, 1, quick_cfg(8));
    let clean_report = clean.run().unwrap();
    assert_eq!(clean_report.steps, 8);
    assert!(!clean_report.recovery.any(), "clean run must ledger nothing");

    let mut chaotic = mk(4, 1, 1, cfg);
    let schedule = FaultSchedule {
        seed: 0,
        train: vec![
            FaultEvent { step: 3, kind: FaultKind::TransientOom, rank: 0, count: 2 },
            FaultEvent { step: 4, kind: FaultKind::CommStall, rank: 2, count: 1 },
            FaultEvent { step: 5, kind: FaultKind::CorruptPayload, rank: 0, count: 1 },
            FaultEvent { step: 5, kind: FaultKind::Straggler, rank: 3, count: 1 },
            FaultEvent { step: 6, kind: FaultKind::RankCrash, rank: 1, count: 1 },
        ],
        serve: vec![],
    };
    chaotic.with_faults(schedule).unwrap();
    let report = chaotic.run().unwrap();

    // elastic recovery shrank the fleet but kept the effective batch
    assert_eq!(chaotic.plan.dp, 2, "dp must shrink past the dead rank");
    assert_eq!(chaotic.plan.accum, 2, "accum must keep E = dp * accum");
    assert_eq!(chaotic.step, 8);

    // bitwise: the interrupted-with-faults run converged to the twin
    assert_same_state(&clean, &chaotic, "chaos vs clean");
    assert_eq!(
        clean_report.final_loss.to_bits(),
        report.final_loss.to_bits(),
        "final loss"
    );

    // the ledger accounts for every absorbed event
    let rec = &report.recovery;
    assert_eq!(rec.retries, 3, "2 oom + 1 stall retries");
    assert_eq!(rec.comm_timeouts, 1);
    assert_eq!(rec.retransmits, 1, "CRC guard must catch the flipped bit");
    assert_eq!(rec.stragglers, 1);
    assert_eq!(rec.rank_crashes, 1);
    assert_eq!(rec.lost_steps, 1, "crash at step 6 rolls back to ckpt 4 from step 5");
    assert!(rec.recovery_seconds > 0.0);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn armed_empty_schedule_is_bitwise_inert() {
    // arming the fault plane with nothing scheduled must not perturb a
    // single bit: the injector seam is on the path, the events are not
    let mut plain = mk(2, 1, 2, quick_cfg(4));
    plain.run().unwrap();
    let mut armed = mk(2, 1, 2, quick_cfg(4));
    armed.with_faults(FaultSchedule::default()).unwrap();
    let report = armed.run().unwrap();
    assert_same_state(&plain, &armed, "armed-empty vs plain");
    assert!(!report.recovery.any());
}

#[test]
fn crash_without_checkpoint_plane_is_a_structured_error() {
    // a permanent rank loss with no checkpoint_dir cannot recover: the
    // trainer must surface a config error naming the missing plane —
    // never hang on the dead rank, never panic
    let mut t = mk(2, 1, 1, quick_cfg(4));
    t.with_faults(FaultSchedule {
        seed: 0,
        train: vec![FaultEvent {
            step: 2,
            kind: FaultKind::RankCrash,
            rank: 0,
            count: 1,
        }],
        serve: vec![],
    })
    .unwrap();
    let err = t.run().unwrap_err();
    assert!(
        err.to_string().contains("checkpoint"),
        "error must name the missing checkpoint plane: {err}"
    );
}

#[test]
fn synthesized_schedule_survives_end_to_end() {
    // the CI chaos path: a seed-synthesized schedule (>=1 crash, the
    // requested transients) drives the full recovery machinery and still
    // converges bitwise to the fault-free twin
    let dir = tempdir("synth");
    let mut cfg = quick_cfg(8);
    cfg.checkpoint_every = 2;
    cfg.checkpoint_dir = Some(dir.clone());

    let schedule = FaultSchedule::synthesize(17, 8, 4, 3, 0);
    schedule.validate(4).unwrap();
    assert!(
        schedule
            .train
            .iter()
            .any(|e| e.kind == FaultKind::RankCrash),
        "synthesized schedule must carry a permanent crash"
    );

    let mut clean = mk(4, 1, 1, quick_cfg(8));
    clean.run().unwrap();
    let mut chaotic = mk(4, 1, 1, cfg);
    chaotic.with_faults(schedule).unwrap();
    let report = chaotic.run().unwrap();
    assert_eq!(chaotic.step, 8);
    assert!(report.recovery.rank_crashes >= 1);
    assert_same_state(&clean, &chaotic, "synthesized chaos vs clean");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn heartbeat_executor_fails_fast_on_dead_rank() {
    use fastfold::dap::executor::parallel_ranks_with_heartbeat;
    // all alive: bitwise the plain sweep, and every rank ticked its beat
    let hb = Heartbeats::new(4);
    let out =
        parallel_ranks_with_heartbeat(2, 4, &hb, 7, |r| Ok(r * 10)).unwrap();
    assert_eq!(out, vec![0, 10, 20, 30]);
    for r in 0..4 {
        assert_eq!(hb.beats(r), 1, "rank {r} must have ticked");
    }
    // a dead rank surfaces RankLost instead of executing
    hb.mark_dead(2);
    let err = parallel_ranks_with_heartbeat(2, 4, &hb, 9, |r| Ok(r * 10))
        .unwrap_err();
    match err {
        fastfold::Error::RankLost { rank, step } => {
            assert_eq!((rank, step), (2, 9));
        }
        other => panic!("expected RankLost, got: {other}"),
    }
    // the dead rank took no work: its beat never advanced
    assert_eq!(hb.beats(2), 1);
}
