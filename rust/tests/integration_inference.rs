//! Inference-path integration: fused vs naive full model, chunk planning
//! against the memory model, Table V verdict wiring.

use fastfold::config::ModelConfig;
use fastfold::inference::{autochunk, chunking, single_device_forward};
use fastfold::perfmodel::{GpuSpec, MemoryModel};
use fastfold::runtime::Runtime;
use fastfold::train::DataGen;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

#[test]
fn fused_and_naive_model_agree() {
    let Some(rt) = runtime() else { return };
    let params = rt.manifest.load_params("tiny").unwrap();
    let mut gen = DataGen::new(ModelConfig::tiny(), 13);
    let batch = gen.next_batch();
    let (m_f, z_f) =
        single_device_forward(&rt, "tiny", &params, &batch.msa_tokens, false).unwrap();
    let (m_n, z_n) =
        single_device_forward(&rt, "tiny", &params, &batch.msa_tokens, true).unwrap();
    assert!(m_f.max_abs_diff(&m_n) < 1e-3, "{}", m_f.max_abs_diff(&m_n));
    assert!(z_f.max_abs_diff(&z_n) < 1e-3);
}

#[test]
fn logits_shapes_match_config() {
    let Some(rt) = runtime() else { return };
    let cfg = ModelConfig::tiny();
    let params = rt.manifest.load_params("tiny").unwrap();
    let mut gen = DataGen::new(cfg.clone(), 17);
    let batch = gen.next_batch();
    let (msa_logits, dist_logits) =
        single_device_forward(&rt, "tiny", &params, &batch.msa_tokens, false).unwrap();
    assert_eq!(msa_logits.shape, vec![cfg.n_seq, cfg.n_res, cfg.msa_vocab]);
    assert_eq!(dist_logits.shape, vec![cfg.n_res, cfg.n_res, cfg.n_dist_bins]);
}

#[test]
fn table5_verdicts() {
    // memory-model OOM pattern of paper Table V
    let mem = MemoryModel::default();
    let gpu = GpuSpec::a100_40g();
    // baselines (with best-effort chunking)
    assert!(chunking::plan_chunks(&ModelConfig::inference(2560), &mem, &gpu).is_some());
    assert!(chunking::plan_chunks(&ModelConfig::inference(3072), &mem, &gpu).is_none());
    // FastFold DAP
    assert!(chunking::memory_verdict(3072, 8, 1, &mem, &gpu).is_ok());
    assert!(chunking::memory_verdict(4096, 8, 1, &mem, &gpu).is_ok());
    assert!(chunking::memory_verdict(4096, 4, 1, &mem, &gpu).is_err());
}

#[test]
fn autochunk_table5_oom_boundary_regression() {
    // the planner must reproduce the exact Table V OOM pattern: per-module
    // chunking buys nothing past 3072 on one device (triangle-mult working
    // set is irreducible), and the DAP verdicts are unchanged
    let mem = MemoryModel::default();
    let gpu = GpuSpec::a100_40g();
    let at = |n, dap| autochunk::plan(&ModelConfig::inference(n), &mem, &gpu, dap);
    assert!(at(2560, 1).is_ok(), "2560 single should fit with chunking");
    assert!(at(3072, 1).is_err(), "3072 single should OOM");
    assert!(at(3584, 1).is_err(), "3584 single should OOM");
    assert!(at(4096, 1).is_err(), "4096 single should OOM");
    assert!(at(3584, 4).is_ok(), "3584 DAP-4 should fit");
    assert!(at(4096, 4).is_err(), "4096 DAP-4 should OOM");
    assert!(at(4096, 8).is_ok(), "4096 DAP-8 should fit");
}

#[test]
fn autochunk_meets_paper_memory_claim() {
    // §IV acceptance: ≥80% modeled peak reduction vs the naive unchunked
    // baseline at 2048 residues on an A100-40G, with a sane latency cost
    let mem = MemoryModel::default();
    let gpu = GpuSpec::a100_40g();
    let plan = autochunk::plan(&ModelConfig::inference(2048), &mem, &gpu, 1).unwrap();
    assert!(plan.fits());
    assert!(
        plan.savings_frac() >= 0.80,
        "savings {:.3} ({})",
        plan.savings_frac(),
        plan.summary()
    );
    assert!(plan.latency_factor >= 1.0 && plan.latency_factor < 1.6);
    // and the serialized form round-trips through the crate JSON codec
    let j = fastfold::json::Json::parse(&plan.to_json().to_string()).unwrap();
    assert_eq!(autochunk::AutoChunkPlan::from_json(&j).unwrap(), plan);
}

#[test]
fn guarded_single_device_forward() {
    // the AutoChunk memory guard wraps the executed path: tiny preset
    // plans trivially (no chunking) and runs when artifacts exist
    let mem = MemoryModel::default();
    let gpu = GpuSpec::a100_40g();
    let plan = fastfold::inference::single::memory_guard(
        &ModelConfig::tiny(), &mem, &gpu, autochunk::CHUNK_HEADROOM).unwrap();
    assert!(!plan.is_chunked());
    let Some(rt) = runtime() else { return };
    let params = rt.manifest.load_params("tiny").unwrap();
    let mut gen = DataGen::new(ModelConfig::tiny(), 23);
    let batch = gen.next_batch();
    let (m, z, plan) = fastfold::inference::single::single_device_forward_guarded(
        &rt, "tiny", &params, &batch.msa_tokens, false, &mem, &gpu,
        autochunk::CHUNK_HEADROOM,
    )
    .unwrap();
    assert!(plan.fits());
    assert!(m.data().iter().all(|x| x.is_finite()));
    assert!(z.data().iter().all(|x| x.is_finite()));
}

#[test]
fn guarded_forward_respects_tuned_memory_model() {
    // Regression: the guard used to hardcode `MemoryModel::default()`,
    // silently ignoring the caller's tuned model. A model whose fixed
    // overhead alone exceeds device capacity must make the guard refuse
    // *before* touching params or artifacts — so this runs without the
    // artifact tree, against a minimal manifest.
    let dir = std::env::temp_dir().join(format!(
        "fastfold_guard_regression_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts":{},"params":{},"dap_schedule":[],"configs":{}}"#,
    )
    .unwrap();
    let rt = Runtime::new(dir.to_str().unwrap()).unwrap();

    let tuned = MemoryModel { fixed_overhead: 1e18, ..MemoryModel::default() };
    let gpu = GpuSpec::a100_40g();
    let tokens = fastfold::IntTensor::new(vec![8, 16], vec![0; 128]).unwrap();
    let err = fastfold::inference::single::single_device_forward_guarded(
        &rt, "tiny", &[], &tokens, false, &tuned, &gpu, autochunk::CHUNK_HEADROOM,
    )
    .unwrap_err();
    assert!(
        matches!(err, fastfold::Error::SimOom { .. }),
        "tuned memory model must drive the verdict, got: {err}"
    );
    // sanity: the same call under the default model passes the guard and
    // only then fails on the (intentionally empty) param manifest
    let err = fastfold::inference::single::single_device_forward_guarded(
        &rt, "tiny", &[], &tokens, false, &MemoryModel::default(), &gpu,
        autochunk::CHUNK_HEADROOM,
    )
    .unwrap_err();
    assert!(
        matches!(err, fastfold::Error::Manifest(_)),
        "default model should pass the guard, got: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn small_preset_also_runs() {
    let Some(rt) = runtime() else { return };
    if !rt.manifest.artifacts.contains_key("small/block_fwd") {
        eprintln!("skipping: small preset not exported");
        return;
    }
    let params = rt.manifest.load_params("small").unwrap();
    let mut gen = DataGen::new(ModelConfig::small(), 19);
    let batch = gen.next_batch();
    let (m, z) =
        single_device_forward(&rt, "small", &params, &batch.msa_tokens, false).unwrap();
    assert!(m.data().iter().all(|x| x.is_finite()));
    assert!(z.data().iter().all(|x| x.is_finite()));
}
