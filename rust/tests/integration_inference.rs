//! Inference-path integration: fused vs naive full model, chunk planning
//! against the memory model, Table V verdict wiring.

use fastfold::config::ModelConfig;
use fastfold::inference::{chunking, single_device_forward};
use fastfold::perfmodel::{GpuSpec, MemoryModel};
use fastfold::runtime::Runtime;
use fastfold::train::DataGen;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

#[test]
fn fused_and_naive_model_agree() {
    let Some(rt) = runtime() else { return };
    let params = rt.manifest.load_params("tiny").unwrap();
    let mut gen = DataGen::new(ModelConfig::tiny(), 13);
    let batch = gen.next_batch();
    let (m_f, z_f) =
        single_device_forward(&rt, "tiny", &params, &batch.msa_tokens, false).unwrap();
    let (m_n, z_n) =
        single_device_forward(&rt, "tiny", &params, &batch.msa_tokens, true).unwrap();
    assert!(m_f.max_abs_diff(&m_n) < 1e-3, "{}", m_f.max_abs_diff(&m_n));
    assert!(z_f.max_abs_diff(&z_n) < 1e-3);
}

#[test]
fn logits_shapes_match_config() {
    let Some(rt) = runtime() else { return };
    let cfg = ModelConfig::tiny();
    let params = rt.manifest.load_params("tiny").unwrap();
    let mut gen = DataGen::new(cfg.clone(), 17);
    let batch = gen.next_batch();
    let (msa_logits, dist_logits) =
        single_device_forward(&rt, "tiny", &params, &batch.msa_tokens, false).unwrap();
    assert_eq!(msa_logits.shape, vec![cfg.n_seq, cfg.n_res, cfg.msa_vocab]);
    assert_eq!(dist_logits.shape, vec![cfg.n_res, cfg.n_res, cfg.n_dist_bins]);
}

#[test]
fn table5_verdicts() {
    // memory-model OOM pattern of paper Table V
    let mem = MemoryModel::default();
    let gpu = GpuSpec::a100_40g();
    // baselines (with best-effort chunking)
    assert!(chunking::plan_chunks(&ModelConfig::inference(2560), &mem, &gpu).is_some());
    assert!(chunking::plan_chunks(&ModelConfig::inference(3072), &mem, &gpu).is_none());
    // FastFold DAP
    assert!(chunking::memory_verdict(3072, 8, 1, &mem, &gpu).is_ok());
    assert!(chunking::memory_verdict(4096, 8, 1, &mem, &gpu).is_ok());
    assert!(chunking::memory_verdict(4096, 4, 1, &mem, &gpu).is_err());
}

#[test]
fn small_preset_also_runs() {
    let Some(rt) = runtime() else { return };
    if !rt.manifest.artifacts.contains_key("small/block_fwd") {
        eprintln!("skipping: small preset not exported");
        return;
    }
    let params = rt.manifest.load_params("small").unwrap();
    let mut gen = DataGen::new(ModelConfig::small(), 19);
    let batch = gen.next_batch();
    let (m, z) =
        single_device_forward(&rt, "small", &params, &batch.msa_tokens, false).unwrap();
    assert!(m.data.iter().all(|x| x.is_finite()));
    assert!(z.data.iter().all(|x| x.is_finite()));
}
