//! Serve-daemon suite: lifecycle, cache, and determinism properties.
//!
//! The daemon's contract is that everything interesting — admission,
//! backpressure shedding, deadline expiry, cancellation, scheduling,
//! cache hits — is decided by a pure single-threaded simulation on the
//! virtual clock, and the threaded executor merely replays those
//! decisions. These tests pin the contract down:
//!
//! - outputs are bit-for-bit identical to the single-threaded run at
//!   any thread budget and any trace-file arrival order;
//! - no request is starved past `max_bypass`, at any `max_bypass`;
//! - cancelled, expired, shed, and rejected requests never construct a
//!   backend (counted at the factory seam);
//! - a cache hit is bit-identical to recomputing, distinct requests
//!   with equal shapes never collide, the byte budget holds exactly
//!   under load, and a warm replay hits more than a cold one;
//! - `fastfold loadgen` writes a byte-identical trace and ledger across
//!   runs and thread counts, and the 100k quick trace replays to a
//!   complete ledger in tier-1.

use fastfold::config::{ParallelConfig, RunConfig, ServeConfig};
use fastfold::faults::{FaultSchedule, ServeFaultEvent};
use fastfold::inference::engine::daemon::{
    self, DaemonConfig, Disposition, TraceEvent, CACHE_HIT_LATENCY,
    DEFAULT_BACKOFF_BASE, DEFAULT_BREAKER_COOLDOWN, DEFAULT_BREAKER_THRESHOLD,
    DEFAULT_MAX_RETRIES, FAULT_DETECT_LATENCY,
};
use fastfold::inference::engine::loadgen::{self, LoadgenSpec};
use fastfold::inference::engine::{
    plan_batch, BackendFactory, BackendKind, ChaosFactory, Engine, InferBackend, InferOutput,
    InferRequest, Placement, PlacementPlanner, ResultCache, SchedPolicy,
};
use fastfold::metrics::percentile;
use fastfold::runtime::Runtime;
use fastfold::{HostTensor, IntTensor, Result};
use std::sync::atomic::{AtomicUsize, Ordering};

// ---------------------------------------------------------------- helpers

/// A Runtime over a minimal (artifact-free) manifest: enough for the
/// daemon's planning/simulation machinery, which never executes HLO.
fn stub_runtime(tag: &str) -> (Runtime, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(format!(
        "fastfold_daemon_{tag}_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts":{},"params":{},"dap_schedule":[],"configs":{}}"#,
    )
    .unwrap();
    let rt = Runtime::new(dir.to_str().unwrap()).unwrap();
    (rt, dir)
}

/// Deterministic pure-host backend (same shape as the serve_engine
/// fake): output derives only from request identity, chosen backend,
/// and the token stream — never from thread timing.
struct FakeBackend {
    name: String,
    seed: u64,
    priority: u32,
}

impl InferBackend for FakeBackend {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn infer(&self, tokens: &IntTensor) -> Result<InferOutput> {
        let a = self.seed as f32;
        let b: f32 = tokens.data.iter().map(|&t| t as f32).sum();
        let c = self.name.bytes().map(|x| x as u32).sum::<u32>() as f32;
        let m = HostTensor::new(vec![2, 2], vec![a, b, c, self.priority as f32])?;
        let z = HostTensor::new(vec![2], vec![a + b, c * 0.5])?;
        Ok(InferOutput {
            msa_logits: m,
            dist_logits: z,
            note: Some(format!("fake:{}", self.name)),
        })
    }
}

/// [`FakeBackend`] factory that counts constructions: the proof that
/// cancelled/expired/shed/rejected/cached requests never reach a
/// backend is `made() == |Completed non-cached|`.
struct CountingFactory {
    made: AtomicUsize,
}

impl CountingFactory {
    fn new() -> Self {
        CountingFactory { made: AtomicUsize::new(0) }
    }

    fn made(&self) -> usize {
        self.made.load(Ordering::SeqCst)
    }
}

impl BackendFactory for CountingFactory {
    fn make<'a>(
        &'a self,
        req: &InferRequest,
        placement: &Placement,
        _rank_threads: usize,
    ) -> Result<Box<dyn InferBackend + 'a>> {
        self.made.fetch_add(1, Ordering::SeqCst);
        Ok(Box::new(FakeBackend {
            name: placement.backend.name(),
            seed: req.seed,
            priority: req.priority,
        }))
    }
}

fn engine_with(rt: &Runtime, policy: SchedPolicy, threads: usize) -> Engine<'_> {
    let cfg = RunConfig {
        serve: ServeConfig { policy, ..Default::default() },
        parallel: ParallelConfig { threads, ..Default::default() },
        ..Default::default()
    };
    Engine::new(rt, &cfg).expect("engine")
}

fn default_planner() -> PlacementPlanner {
    PlacementPlanner::from_run_config(&RunConfig::default()).expect("default planner")
}

fn dcfg(policy: SchedPolicy, max_bypass: usize, lanes: usize, cache_bytes: usize) -> DaemonConfig {
    DaemonConfig {
        policy,
        max_bypass,
        lanes,
        queue_cap: 0,
        cache_bytes,
        cache_hit_latency: CACHE_HIT_LATENCY,
        faults: None,
        max_retries: DEFAULT_MAX_RETRIES,
        breaker_threshold: DEFAULT_BREAKER_THRESHOLD,
        breaker_cooldown: DEFAULT_BREAKER_COOLDOWN,
        backoff_base: DEFAULT_BACKOFF_BASE,
        fault_detect_latency: FAULT_DETECT_LATENCY,
    }
}

/// A tiny-preset request with a chosen seed (the fake backend bakes the
/// seed into its output bits, so equal seeds ⇒ equal content ⇒ cache
/// hit, distinct seeds ⇒ distinct bits).
fn req(id: &str, seed: u64) -> InferRequest {
    let mut r = InferRequest::new(id, "tiny");
    r.seed = seed;
    r
}

fn small_trace(requests: usize, seed: u64) -> Vec<TraceEvent> {
    let mut spec = LoadgenSpec::new(requests, seed);
    spec.window = 64;
    loadgen::synthesize(&default_planner(), &spec)
}

// ------------------------------------------------------------ simulation

#[test]
fn modeled_replay_is_arrival_order_invariant() {
    // a trace file shuffled on disk must replay identically: the
    // simulation re-sorts by arrival before anything else looks at it
    let planner = default_planner();
    let cfg = dcfg(SchedPolicy::Sjf, 4, 4, 1 << 40);
    let mut trace = small_trace(300, 5);
    // drop µs-rounded arrival ties: with ties the *file order* is the
    // tiebreak (stable sort), so a reversed file legitimately differs
    trace.dedup_by(|next, prev| next.arrival == prev.arrival);
    let mut reversed = trace.clone();
    reversed.reverse();

    let a = daemon::simulate(&planner, &cfg, &trace);
    let b = daemon::simulate(&planner, &cfg, &reversed);
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.cache.hits, b.cache.hits);
    let dispatched_ids = |r: &daemon::DaemonReport, t: &[TraceEvent]| -> Vec<String> {
        r.dispatch_order.iter().map(|&i| t[i].req.id.clone()).collect()
    };
    assert_eq!(dispatched_ids(&a, &trace), dispatched_ids(&b, &reversed));
    // per-id lifecycle identical
    let by_id = |r: &daemon::DaemonReport| -> std::collections::BTreeMap<String, String> {
        r.outcomes
            .iter()
            .map(|o| (o.id.clone(), format!("{:?}@{:?}->{:?}", o.disposition, o.dispatch, o.finish)))
            .collect()
    };
    assert_eq!(by_id(&a), by_id(&b));
}

#[test]
fn daemon_dispatch_matches_batch_plan_at_zero_arrivals() {
    // with everything arriving at t=0, uniform priority, and the cache
    // off, the continuous daemon must degenerate to the one-shot batch
    // engine: same dispatch order under both policies at any bypass
    let planner = default_planner();
    let mut reqs = vec![
        req("preset-a", 3),
        req("preset-b", 5),
        req("long-2048", 7),
        req("dist-4096", 11),
        req("dist-3072", 13),
        req("too-big-8192", 17),
    ];
    reqs[2].model_len = Some(2048);
    reqs[3].model_len = Some(4096);
    reqs[4].model_len = Some(3072);
    reqs[5].model_len = Some(8192);
    let trace: Vec<TraceEvent> =
        reqs.iter().map(|r| TraceEvent::at(0.0, r.clone())).collect();
    for policy in [SchedPolicy::Fifo, SchedPolicy::Sjf] {
        for max_bypass in [0usize, 2, 100] {
            let plan = plan_batch(&planner, policy, max_bypass, 2, &reqs);
            let cfg = dcfg(policy, max_bypass, 2, 0);
            let report = daemon::simulate(&planner, &cfg, &trace);
            assert_eq!(
                report.dispatch_order, plan.order,
                "policy={} max_bypass={max_bypass}",
                policy.name()
            );
        }
    }
}

#[test]
fn starvation_bound_holds_at_any_max_bypass() {
    // satellite property: no request — completed, expired, or cancelled
    // after admission — is overtaken by more than max_bypass younger
    // dispatches, across a priority-mixed SJF workload
    let planner = default_planner();
    let trace = small_trace(400, 5);
    for max_bypass in [0usize, 1, 3] {
        let cfg = dcfg(SchedPolicy::Sjf, max_bypass, 4, 1 << 40);
        let report = daemon::simulate(&planner, &cfg, &trace);
        for o in &report.outcomes {
            assert!(
                o.bypassed <= max_bypass,
                "'{}' bypassed {} times at max_bypass={max_bypass}",
                o.id,
                o.bypassed
            );
        }
    }
}

// ------------------------------------------------------------- lifecycle

/// The hand-built lifecycle trace (one lane, queue cap 3, FIFO):
/// e0 executes, e1 duplicates e0's content (cache hit), e2 is cancelled
/// before arrival takes effect, e3 expires queued behind e0, e4 is shed
/// by backpressure.
fn lifecycle_trace() -> Vec<TraceEvent> {
    let mut e2 = TraceEvent::at(0.0, req("pre-cancelled", 5));
    e2.cancel_at = Some(0.0);
    let mut e3 = TraceEvent::at(0.0, req("expires", 7));
    e3.deadline = Some(1e-9);
    vec![
        TraceEvent::at(0.0, req("producer", 3)),
        TraceEvent::at(0.0, req("dup", 3)),
        e2,
        e3,
        TraceEvent::at(0.0, req("shed-me", 11)),
    ]
}

fn lifecycle_cfg(cache_bytes: usize) -> DaemonConfig {
    DaemonConfig {
        policy: SchedPolicy::Fifo,
        max_bypass: 4,
        lanes: 1,
        queue_cap: 3,
        cache_bytes,
        cache_hit_latency: CACHE_HIT_LATENCY,
        faults: None,
        max_retries: DEFAULT_MAX_RETRIES,
        breaker_threshold: DEFAULT_BREAKER_THRESHOLD,
        breaker_cooldown: DEFAULT_BREAKER_COOLDOWN,
        backoff_base: DEFAULT_BACKOFF_BASE,
        fault_detect_latency: FAULT_DETECT_LATENCY,
    }
}

#[test]
fn terminal_requests_never_reach_a_backend() {
    let (rt, dir) = stub_runtime("lifecycle");
    let engine = engine_with(&rt, SchedPolicy::Fifo, 2);
    let factory = CountingFactory::new();
    let report = engine
        .serve_trace_with(&lifecycle_cfg(1 << 40), &lifecycle_trace(), &factory)
        .unwrap();

    let disp = |i: usize| &report.sim.outcomes[i].disposition;
    assert_eq!(*disp(0), Disposition::Completed { cached: false, deadline_missed: false });
    assert_eq!(*disp(1), Disposition::Completed { cached: true, deadline_missed: false });
    assert_eq!(*disp(2), Disposition::Cancelled);
    assert_eq!(*disp(3), Disposition::Expired);
    assert_eq!(*disp(4), Disposition::Shed);

    // exactly one backend was ever constructed: the producer
    assert_eq!(factory.made(), 1);
    assert!(report.outputs[2].is_none());
    assert!(report.outputs[3].is_none());
    assert!(report.outputs[4].is_none());

    // the hit occupies its lane for the modeled hit latency, not the
    // request's service time
    let produced = report.sim.outcomes[0].finish.unwrap();
    let hit = report.sim.outcomes[1].finish.unwrap();
    assert!((hit - (produced + CACHE_HIT_LATENCY)).abs() < 1e-12);

    // the only deadline-carrying request expired -> miss rate 1.0
    assert!((report.sim.deadline_miss_rate() - 1.0).abs() < 1e-12);

    // ServeStats FLOP exclusion at the daemon level: the aggregate
    // numerator counts the producer once, never the cache hit
    let producer_flops = report.sim.outcomes[0].placement.as_ref().unwrap().modeled_flops;
    assert!((report.stats.total_modeled_flops() - producer_flops).abs() < 1e-3);
    assert_eq!(report.stats.cache_hits(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

// ----------------------------------------------------------------- cache

#[test]
fn cache_hit_is_bit_identical_to_recompute() {
    let (rt, dir) = stub_runtime("hit_bits");
    let engine = engine_with(&rt, SchedPolicy::Fifo, 2);
    let trace = lifecycle_trace();

    let cached_factory = CountingFactory::new();
    let cached = engine
        .serve_trace_with(&lifecycle_cfg(1 << 40), &trace, &cached_factory)
        .unwrap();
    let uncached_factory = CountingFactory::new();
    let uncached = engine
        .serve_trace_with(&lifecycle_cfg(0), &trace, &uncached_factory)
        .unwrap();
    assert_eq!(cached_factory.made(), 1);
    assert_eq!(uncached_factory.made(), 2, "cache off -> the dup recomputes");

    let bits = |r: &daemon::TraceServeReport, i: usize| -> (Vec<f32>, Vec<f32>) {
        let (m, z) = r.outputs[i].as_ref().unwrap().as_ref().unwrap();
        (m.data().to_vec(), z.data().to_vec())
    };
    // the served hit is bit-for-bit the recomputed answer
    assert_eq!(bits(&cached, 1), bits(&uncached, 1));
    // and bit-for-bit its producer's answer
    assert_eq!(bits(&cached, 1), bits(&cached, 0));
    assert!(cached.notes[1].as_ref().unwrap().contains("cache hit"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn equal_shapes_distinct_content_never_collide() {
    // two requests with identical modeled shape but different content
    // (seed) must both execute and produce different bits
    let (rt, dir) = stub_runtime("no_collide");
    let engine = engine_with(&rt, SchedPolicy::Fifo, 1);
    let trace =
        vec![TraceEvent::at(0.0, req("a", 3)), TraceEvent::at(0.0, req("b", 4))];
    let factory = CountingFactory::new();
    let report = engine
        .serve_trace_with(&lifecycle_cfg(1 << 40), &trace, &factory)
        .unwrap();
    assert_eq!(factory.made(), 2);
    assert_eq!(report.sim.cache_hits(), 0);
    let m = |i: usize| -> Vec<f32> {
        report.outputs[i].as_ref().unwrap().as_ref().unwrap().0.data().to_vec()
    };
    assert_ne!(m(0), m(1));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cache_respects_byte_budget_under_load() {
    // 64 MB is a few mid-size results: the replay must evict, and the
    // resident set must never exceed the budget
    let planner = default_planner();
    let budget = 64_000_000usize;
    let cfg = dcfg(SchedPolicy::Sjf, 4, 4, budget);
    let report = daemon::simulate(&planner, &cfg, &small_trace(400, 5));
    assert!(report.cache.insertions > 0);
    assert!(report.cache.evictions > 0, "budget should force eviction");
    assert!(
        report.cache.peak_bytes <= budget,
        "peak {} over budget {budget}",
        report.cache.peak_bytes
    );
    assert!(report.cache.used_bytes <= report.cache.peak_bytes);
}

#[test]
fn warm_replay_hits_more_than_cold() {
    // satellite: cold-vs-warm replay reports the expected hit curve —
    // the warm pass reuses the cold cache and must hit strictly more
    let planner = default_planner();
    let cfg = dcfg(SchedPolicy::Sjf, 4, 4, 1 << 40);
    let trace = small_trace(400, 5);
    let mut cache = ResultCache::new(cfg.cache_bytes);
    let cold = daemon::simulate_with_cache(&planner, &cfg, &trace, &mut cache);
    let warm_trace = daemon::shift_trace(&trace, cold.makespan);
    let warm = daemon::simulate_with_cache(&planner, &cfg, &warm_trace, &mut cache);

    assert!(cold.cache_hits() > 0, "dup_frac must produce cold hits");
    assert!(warm.cache_hits() > cold.cache_hits());
    let rate = |r: &daemon::DaemonReport| r.cache_hits() as f64 / r.completed() as f64;
    assert!(rate(&warm) > rate(&cold));
    // the warm curve starts hot; the cold curve has to climb
    let (cold_curve, warm_curve) = (loadgen::hit_curve(&cold), loadgen::hit_curve(&warm));
    assert!(warm_curve[0] >= cold_curve[0]);
    assert!(warm_curve[0] > 0.5, "warm first decile should be mostly hits");
}

// ----------------------------------------------------------- determinism

#[test]
fn executed_trace_is_thread_invariant() {
    // tentpole acceptance: bit-for-bit identical outputs at any thread
    // budget over a generated trace with every disposition in play
    let (rt, dir) = stub_runtime("threads");
    let trace = small_trace(120, 11);
    let cfg = dcfg(SchedPolicy::Sjf, 4, 4, 1 << 40);
    let reference = engine_with(&rt, SchedPolicy::Sjf, 1)
        .serve_trace_with(&cfg, &trace, &CountingFactory::new())
        .unwrap();
    for threads in [2usize, 5] {
        let run = engine_with(&rt, SchedPolicy::Sjf, threads)
            .serve_trace_with(&cfg, &trace, &CountingFactory::new())
            .unwrap();
        assert_eq!(run.sim.dispatch_order, reference.sim.dispatch_order);
        for (a, b) in run.sim.outcomes.iter().zip(reference.sim.outcomes.iter()) {
            assert_eq!(a.disposition, b.disposition, "'{}' @ threads={threads}", a.id);
        }
        for (i, (a, b)) in run.outputs.iter().zip(reference.outputs.iter()).enumerate() {
            match (a, b) {
                (None, None) => {}
                (Some(Ok((am, az))), Some(Ok((bm, bz)))) => {
                    assert_eq!(am.data(), bm.data(), "event {i} @ threads={threads}");
                    assert_eq!(az.data(), bz.data(), "event {i} @ threads={threads}");
                }
                (Some(Err(ae)), Some(Err(be))) => {
                    assert_eq!(ae.to_string(), be.to_string());
                }
                _ => panic!("disposition of event {i} changed with threads"),
            }
        }
        assert_eq!(run.notes, reference.notes);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn loadgen_cli_is_byte_deterministic_across_threads() {
    // satellite acceptance: same seed => byte-identical trace file and
    // byte-identical BENCH_serve.json across runs and thread counts
    let dir = std::env::temp_dir().join(format!(
        "fastfold_loadgen_cli_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let run = |tag: &str, threads: &str| -> (Vec<u8>, Vec<u8>) {
        let trace = dir.join(format!("trace_{tag}.jsonl"));
        let bench = dir.join(format!("bench_{tag}.json"));
        let status = std::process::Command::new(env!("CARGO_BIN_EXE_fastfold"))
            .args([
                "loadgen",
                "--requests",
                "1500",
                "--seed",
                "9",
                "--threads",
                threads,
                "--out",
                trace.to_str().unwrap(),
                "--bench-out",
                bench.to_str().unwrap(),
            ])
            .status()
            .expect("spawn fastfold loadgen");
        assert!(status.success(), "loadgen ({tag}) failed");
        (std::fs::read(&trace).unwrap(), std::fs::read(&bench).unwrap())
    };
    let (trace_a, bench_a) = run("a", "1");
    let (trace_b, bench_b) = run("b", "6");
    assert!(!trace_a.is_empty() && !bench_a.is_empty());
    assert_eq!(trace_a, trace_b, "trace bytes drift with --threads");
    assert_eq!(bench_a, bench_b, "ledger bytes drift with --threads");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn quick_100k_trace_replays_to_a_complete_ledger() {
    // tentpole acceptance: the >=100k-request modeled trace replays in
    // tier-1 and every request reaches exactly one terminal state
    let planner = default_planner();
    let spec = LoadgenSpec::quick(17);
    let cfg = DaemonConfig::from_run_config(&RunConfig::default(), spec.lanes);
    let (trace, report) = loadgen::generate_and_replay(&planner, &spec, &cfg);
    assert_eq!(trace.len(), 100_000);
    assert_eq!(report.outcomes.len(), 100_000);
    let accounted = report.completed()
        + report.rejected()
        + report.shed()
        + report.expired()
        + report.cancelled();
    assert_eq!(accounted, 100_000);
    assert!(report.cache_hits() > 0);
    let miss = report.deadline_miss_rate();
    assert!((0.0..=1.0).contains(&miss), "miss rate {miss}");
    let sojourns = report.sojourns();
    assert!(!sojourns.is_empty());
    let (p50, p99) =
        (percentile(sojourns.clone(), 50.0), percentile(sojourns, 99.0));
    assert!(p50 <= p99, "p50 {p50} > p99 {p99}");
    // the ledger carries every gated figure
    let doc = loadgen::bench_doc(&spec, &cfg, &report).to_string();
    for key in
        ["\"p50_s\"", "\"p99_s\"", "\"throughput_rps\"", "\"deadline_miss_rate\"", "\"hit_curve\""]
    {
        assert!(doc.contains(key), "missing {key}");
    }
}

// ------------------------------------------------- faults / degraded mode

/// Factory that fails construction for one request id — a deterministic
/// mid-batch backend error, independent of worker pull order.
struct PoisonFactory<'f> {
    inner: &'f CountingFactory,
    poison: &'static str,
}

impl BackendFactory for PoisonFactory<'_> {
    fn make<'a>(
        &'a self,
        req: &InferRequest,
        placement: &Placement,
        rank_threads: usize,
    ) -> Result<Box<dyn InferBackend + 'a>> {
        if req.id == self.poison {
            return Err(fastfold::Error::msg("injected: poison pill"));
        }
        self.inner.make(req, placement, rank_threads)
    }
}

#[test]
fn mid_batch_backend_error_does_not_poison_survivors() {
    // satellite: a backend Err mid-batch must land in exactly its own
    // slot of the drain, and the survivors stay bit-for-bit invariant
    // across thread budgets
    let (rt, dir) = stub_runtime("poison");
    let ids = ["r0", "poison", "r2", "r3", "r4"];
    let trace: Vec<TraceEvent> = ids
        .iter()
        .enumerate()
        .map(|(i, id)| TraceEvent::at(0.0, req(id, 3 + i as u64)))
        .collect();
    let cfg = dcfg(SchedPolicy::Fifo, 4, 2, 0);
    let run = |threads: usize| {
        let counting = CountingFactory::new();
        let factory = PoisonFactory { inner: &counting, poison: "poison" };
        let report = engine_with(&rt, SchedPolicy::Fifo, threads)
            .serve_trace_with(&cfg, &trace, &factory)
            .unwrap();
        (report, counting.made())
    };
    let (reference, made1) = run(1);
    assert_eq!(made1, 4, "the poisoned request constructs no inner backend");
    // the lifecycle is decided pre-execution: the sim books Completed,
    // the failure surfaces only in the output slot and stats.ok
    assert_eq!(reference.sim.completed(), 5);
    for (i, out) in reference.outputs.iter().enumerate() {
        match (trace[i].req.id.as_str(), out) {
            ("poison", Some(Err(e))) => {
                assert!(e.to_string().contains("poison pill"))
            }
            ("poison", _) => panic!("poisoned slot must carry the error"),
            (_, Some(Ok(_))) => {}
            (id, _) => panic!("survivor '{id}' lost its output"),
        }
    }
    for threads in [2usize, 5] {
        let (r, made) = run(threads);
        assert_eq!(made, 4);
        for (i, (a, b)) in
            r.outputs.iter().zip(reference.outputs.iter()).enumerate()
        {
            match (a, b) {
                (Some(Ok((am, az))), Some(Ok((bm, bz)))) => {
                    assert_eq!(am.data(), bm.data(), "event {i}");
                    assert_eq!(az.data(), bz.data(), "event {i}");
                }
                (Some(Err(ae)), Some(Err(be))) => {
                    assert_eq!(ae.to_string(), be.to_string())
                }
                _ => panic!("event {i} outcome changed with threads"),
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn chaos_factory_injects_attempts_in_dispatch_order() {
    // at one worker thread the executor constructs backends in dispatch
    // order, so the schedule's attempt numbering pins the exact victim
    let (rt, dir) = stub_runtime("chaos_seam");
    let trace: Vec<TraceEvent> = (0..3)
        .map(|i| TraceEvent::at(0.0, req(&format!("c{i}"), 20 + i as u64)))
        .collect();
    let cfg = dcfg(SchedPolicy::Fifo, 4, 1, 0);
    let counting = CountingFactory::new();
    let schedule = FaultSchedule {
        seed: 0,
        train: vec![],
        serve: vec![ServeFaultEvent { at: 1, count: 1 }],
    };
    let chaos = ChaosFactory::new(&counting, schedule);
    let report = engine_with(&rt, SchedPolicy::Fifo, 1)
        .serve_trace_with(&cfg, &trace, &chaos)
        .unwrap();
    assert_eq!(chaos.injected(), 1);
    assert_eq!(counting.made(), 2);
    let victim = report.sim.dispatch_order[1];
    for (i, out) in report.outputs.iter().enumerate() {
        match out {
            Some(Err(e)) => {
                assert_eq!(i, victim, "error landed in the wrong slot");
                assert!(e.to_string().contains("injected backend failure"));
            }
            Some(Ok(_)) => assert_ne!(i, victim),
            None => panic!("event {i} was not executed"),
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sim_transient_fault_is_retried_to_completion() {
    // one injected backend failure: the victim requeues with backoff,
    // falls back to a cheaper placement when one exists, and completes
    let planner = default_planner();
    let mut cfg = dcfg(SchedPolicy::Fifo, 4, 1, 0);
    cfg.faults = Some(FaultSchedule {
        seed: 0,
        train: vec![],
        serve: vec![ServeFaultEvent { at: 0, count: 1 }],
    });
    let trace: Vec<TraceEvent> = (0..4)
        .map(|i| TraceEvent::at(0.1 * i as f64, req(&format!("t{i}"), 40 + i as u64)))
        .collect();
    let report = daemon::simulate(&planner, &cfg, &trace);
    assert_eq!(report.completed(), 4, "one transient must not lose requests");
    assert_eq!(report.failed(), 0);
    assert!(report.retries >= 1);
    let first = planner.place(&req("t0", 40)).unwrap();
    if first.backend != BackendKind::Chunked {
        assert!(report.fallbacks >= 1, "retry should fall back from {:?}", first.backend);
    }
    // the no-fault twin reports a fully clean degraded ledger
    let clean =
        daemon::simulate(&planner, &dcfg(SchedPolicy::Fifo, 4, 1, 0), &trace);
    assert_eq!(
        (clean.retries, clean.fallbacks, clean.breaker_shed, clean.failed()),
        (0, 0, 0, 0)
    );
    assert_eq!(clean.completed(), 4);
    assert!(!clean.summary().contains("degraded"));
}

#[test]
fn persistent_failures_trip_the_breaker_and_shed() {
    // every construction attempt fails: retries exhaust into Failed, the
    // breaker opens after the failure streak, and arrivals inside the
    // cooldown window are shed at ingestion — zero hangs, full ledger
    let planner = default_planner();
    let mut cfg = dcfg(SchedPolicy::Fifo, 4, 1, 0);
    cfg.faults = Some(FaultSchedule {
        seed: 0,
        train: vec![],
        serve: vec![ServeFaultEvent { at: 0, count: 1000 }],
    });
    let trace: Vec<TraceEvent> = (0..10)
        .map(|i| TraceEvent::at(0.1 * i as f64, req(&format!("b{i}"), 60 + i as u64)))
        .collect();
    let report = daemon::simulate(&planner, &cfg, &trace);
    assert_eq!(report.completed(), 0);
    assert!(report.failed() >= 1, "exhausted retries must fail the request");
    assert!(report.breaker_shed >= 1, "breaker must shed during cooldown");
    assert!(report.retries >= DEFAULT_MAX_RETRIES);
    // every request still reaches exactly one terminal state
    let accounted = report.completed()
        + report.rejected()
        + report.shed()
        + report.expired()
        + report.cancelled()
        + report.failed();
    assert_eq!(accounted, 10);
    assert!(report.summary().contains("degraded"));
}
