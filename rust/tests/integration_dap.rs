//! THE core integration suite: the rust DAP coordinator (PJRT segments +
//! host collectives + Duality-Async schedule) must reproduce the
//! single-device block executable exactly — forward AND backward — and the
//! full-model distributed inference must match single-device inference
//! (paper §V.D validation).

use fastfold::config::ModelConfig;
use fastfold::dap::DapCoordinator;
use fastfold::rng::Rng;
use fastfold::runtime::Runtime;
use fastfold::tensor::HostTensor;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::new(shape.to_vec(), rng.normal_vec(n, 1.0)).unwrap()
}

struct Setup {
    rt: Runtime,
    cfg: ModelConfig,
    block_params: Vec<HostTensor>,
    m: HostTensor,
    z: HostTensor,
}

fn setup() -> Option<Setup> {
    let rt = runtime()?;
    let cfg = ModelConfig::tiny();
    let params = rt.manifest.load_params("tiny").unwrap();
    let idx = rt.manifest.block_leaf_indices("tiny", 0).unwrap();
    let block_params: Vec<HostTensor> = idx.iter().map(|&i| params[i].clone()).collect();
    let mut rng = Rng::new(11);
    let m = rand_tensor(&mut rng, &[cfg.n_seq, cfg.n_res, cfg.d_msa]);
    let z = rand_tensor(&mut rng, &[cfg.n_res, cfg.n_res, cfg.d_pair]);
    Some(Setup { rt, cfg, block_params, m, z })
}

fn reference_block(s: &Setup) -> (HostTensor, HostTensor) {
    let exe = s.rt.load("tiny/block_fwd").unwrap();
    let mut args = s.block_params.clone();
    args.push(s.m.clone());
    args.push(s.z.clone());
    let out = exe.run_f32(&args).unwrap();
    (out[0].clone(), out[1].clone())
}

#[test]
fn dap_forward_matches_reference_n1_n2_n4() {
    let Some(s) = setup() else { return };
    let (m_ref, z_ref) = reference_block(&s);
    for n in [1usize, 2, 4] {
        let co = DapCoordinator::new(&s.rt, "tiny", n, true).unwrap();
        let mut state = co.shard_inputs(&s.m, &s.z).unwrap();
        co.block_forward(&s.block_params, &mut state).unwrap();
        let (m_out, z_out) = co.unshard(&state).unwrap();
        assert!(
            m_out.max_abs_diff(&m_ref) < 1e-4,
            "n={n} m diff {}",
            m_out.max_abs_diff(&m_ref)
        );
        assert!(
            z_out.max_abs_diff(&z_ref) < 1e-4,
            "n={n} z diff {}",
            z_out.max_abs_diff(&z_ref)
        );
    }
}

#[test]
fn dap_comm_counts_match_design_table3() {
    // DESIGN.md §3 / Table III repro: 5 AllGather + 1 ReduceScatter +
    // 4 All_to_All per block forward — measured from the comm log.
    use fastfold::comm::CommKind;
    let Some(s) = setup() else { return };
    let co = DapCoordinator::new(&s.rt, "tiny", 2, true).unwrap();
    let mut state = co.shard_inputs(&s.m, &s.z).unwrap();
    co.block_forward(&s.block_params, &mut state).unwrap();
    let log = co.comm.log.lock().unwrap();
    assert_eq!(log.count(CommKind::AllGather), 5);
    assert_eq!(log.count(CommKind::ReduceScatter), 1);
    assert_eq!(log.count(CommKind::AllToAll), 4);
}

#[test]
fn duality_async_overlap_improves_simulated_time() {
    let Some(s) = setup() else { return };
    let run = |overlap: bool| -> (f64, f64) {
        let co = DapCoordinator::new(&s.rt, "tiny", 4, overlap).unwrap();
        let mut state = co.shard_inputs(&s.m, &s.z).unwrap();
        co.block_forward(&s.block_params, &mut state).unwrap();
        let tl = co.timeline.lock().unwrap();
        (tl.elapsed(), tl.exposed_comm_seconds)
    };
    let _warmup = run(true); // first executions include PJRT warmup
    let (t_on, exposed_on) = run(true);
    let (t_off, exposed_off) = run(false);
    // comm durations are deterministic (priced from bytes); exec times are
    // measured wall-clock, so allow jitter slack on the total.
    assert!(exposed_on <= exposed_off + 1e-12);
    assert!(
        t_on <= t_off * 1.25 + 1e-6,
        "overlap {t_on} vs sync {t_off}"
    );
}

#[test]
fn threaded_block_forward_bitwise_matches_sequential() {
    // dap ∈ {2,4,8} (where segment artifacts exist): the threaded rank
    // executor + comm worker must produce bit-for-bit the sequential
    // tensors and identical comm-log contents
    let Some(s) = setup() else { return };
    for n in [2usize, 4, 8] {
        let Ok(co_seq) = DapCoordinator::new(&s.rt, "tiny", n, true) else {
            continue; // degree not exported for this preset
        };
        let co_seq = co_seq.with_threads(1);
        let mut st_seq = co_seq.shard_inputs(&s.m, &s.z).unwrap();
        co_seq.block_forward(&s.block_params, &mut st_seq).unwrap();

        let co_thr = DapCoordinator::new(&s.rt, "tiny", n, true)
            .unwrap()
            .with_threads(4);
        let mut st_thr = co_thr.shard_inputs(&s.m, &s.z).unwrap();
        co_thr.block_forward(&s.block_params, &mut st_thr).unwrap();

        assert_eq!(st_seq, st_thr, "n={n}: threaded state diverged");
        let (a, b) = (
            co_seq.comm.log.lock().unwrap(),
            co_thr.comm.log.lock().unwrap(),
        );
        assert_eq!(a.len(), b.len(), "n={n}: comm-log length diverged");
        // per-kind, order-insensitive: the comm worker may interleave its
        // records with main-thread sync collectives
        for kind in [
            fastfold::comm::CommKind::AllGather,
            fastfold::comm::CommKind::ReduceScatter,
            fastfold::comm::CommKind::AllToAll,
            fastfold::comm::CommKind::AllReduce,
            fastfold::comm::CommKind::Broadcast,
        ] {
            assert_eq!(a.count(kind), b.count(kind), "n={n} {kind:?} count");
            assert_eq!(a.bytes_of(kind), b.bytes_of(kind), "n={n} {kind:?} bytes");
        }
    }
}

#[test]
fn dap_backward_matches_reference_vjp() {
    let Some(s) = setup() else { return };
    let mut rng = Rng::new(23);
    let ct_m = rand_tensor(&mut rng, &s.m.shape);
    let ct_z = rand_tensor(&mut rng, &s.z.shape);

    // reference: the block_grad artifact (jax.vjp of the whole block)
    let ref_exe = s.rt.load("tiny/block_grad").unwrap();
    let mut args = s.block_params.clone();
    args.extend([s.m.clone(), s.z.clone(), ct_m.clone(), ct_z.clone()]);
    let ref_out = ref_exe.run_f32(&args).unwrap();
    let np = s.block_params.len();
    let (ref_pg, ref_d) = ref_out.split_at(np);

    for n in [1usize, 2, 4] {
        let co = DapCoordinator::new(&s.rt, "tiny", n, true).unwrap();
        assert!(co.has_backward());
        *co.record.borrow_mut() = true;
        let mut state = co.shard_inputs(&s.m, &s.z).unwrap();
        co.block_forward(&s.block_params, &mut state).unwrap();

        let mut d_state = fastfold::dap::State::new();
        d_state.insert("m".into(), ct_m.split_axis(0, n).unwrap());
        d_state.insert("z".into(), ct_z.split_axis(0, n).unwrap());
        let pg = co.block_backward(&s.block_params, &mut d_state).unwrap();

        // parameter gradients
        assert_eq!(pg.len(), np);
        for (i, (got, want)) in pg.iter().zip(ref_pg.iter()).enumerate() {
            let d = got.max_abs_diff(want);
            let scale = want.data().iter().fold(0.0f32, |a, &b| a.max(b.abs()));
            assert!(
                d < 1e-3 + 1e-3 * scale,
                "n={n} param leaf {i}: diff {d} (scale {scale})"
            );
        }
        // input cotangents
        let dm = HostTensor::concat(&d_state["m"], 0).unwrap();
        let dz = HostTensor::concat(&d_state["z"], 0).unwrap();
        assert!(dm.max_abs_diff(&ref_d[0]) < 1e-3, "n={n} dm");
        assert!(dz.max_abs_diff(&ref_d[1]) < 1e-3, "n={n} dz");
    }
}

#[test]
fn dap_model_forward_matches_single_device() {
    let Some(s) = setup() else { return };
    let params = s.rt.manifest.load_params("tiny").unwrap();
    let mut gen = fastfold::train::DataGen::new(s.cfg.clone(), 5);
    let batch = gen.next_batch();
    let (m_ref, z_ref) = fastfold::inference::single_device_forward(
        &s.rt, "tiny", &params, &batch.msa_tokens, false,
    )
    .unwrap();
    for n in [2usize, 4] {
        let co = DapCoordinator::new(&s.rt, "tiny", n, true).unwrap();
        let (m_d, z_d) = co.model_forward(&params, &batch.msa_tokens).unwrap();
        assert!(m_d.max_abs_diff(&m_ref) < 1e-3, "n={n}");
        assert!(z_d.max_abs_diff(&z_ref) < 1e-3, "n={n}");
    }
}

#[test]
fn indivisible_dap_size_rejected() {
    let Some(s) = setup() else { return };
    assert!(DapCoordinator::new(&s.rt, "tiny", 3, true).is_err());
}
