//! Cross-backend kernel property suite: `SimdHost` must reproduce the
//! `ScalarHost` oracle element-for-element — bit-for-bit for softmax,
//! Adam, and the elementwise helpers (the shared polynomial exp and an
//! identical per-element op order make this exact), tolerance-bounded
//! for LayerNorm (8 Welford lanes vs the oracle's 4 reorder the
//! summation) — across odd lengths, non-multiple-of-8 tails, thread
//! counts {1, 2, 4, 8}, and NaN/inf/denormal inputs. Backends are
//! constructed explicitly (never via the process-global
//! `device::configure`) so the suite is independent of environment and
//! test order.
#![cfg(feature = "simd")]

use fastfold::device::{DeviceBackend, ScalarHost, SimdHost};
use fastfold::rng::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 8];
/// (rows, cols): single elements, odd columns, non-multiple-of-8 tails,
/// and row counts that engage 2..=8 worker bands at the 64-row floor.
const SHAPES: [(usize, usize); 7] =
    [(1, 1), (3, 7), (16, 8), (64, 33), (130, 65), (300, 257), (520, 9)];

/// Plant non-finite and denormal values at irregular strides so they
/// land in lane bodies, scalar tails, and band boundaries alike.
fn special_input(mut x: Vec<f32>) -> Vec<f32> {
    for (i, v) in x.iter_mut().enumerate() {
        match i % 97 {
            13 => *v = f32::NAN,
            29 => *v = f32::INFINITY,
            43 => *v = f32::NEG_INFINITY,
            61 => *v = 1.0e-40,
            71 => *v = -0.0,
            _ => {}
        }
    }
    x
}

#[test]
fn softmax_simd_matches_scalar_bit_for_bit() {
    let oracle = ScalarHost;
    let mut rng = Rng::new(9001);
    for &(rows, cols) in &SHAPES {
        for variant in 0..2 {
            let base = rng.normal_vec(rows * cols, 2.0);
            let x = if variant == 0 { base } else { special_input(base) };
            let scale = 1.0 / (cols as f32).sqrt();
            let mut want = vec![0.0f32; x.len()];
            oracle.softmax_rows(&x, cols, scale, &mut want);
            for &t in &THREADS {
                let be = SimdHost::with_threads(t);
                let mut got = vec![0.0f32; x.len()];
                be.softmax_rows(&x, cols, scale, &mut got);
                for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "softmax rows={rows} cols={cols} t={t} \
                         variant={variant} i={i}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn layernorm_simd_matches_scalar_to_tolerance() {
    let oracle = ScalarHost;
    let mut rng = Rng::new(77);
    for &(rows, cols) in &SHAPES {
        let x = rng.normal_vec(rows * cols, 2.0);
        let g = rng.normal_vec(cols, 1.0);
        let b = rng.normal_vec(cols, 1.0);
        let mut want = vec![0.0f32; x.len()];
        oracle.layernorm_rows(&x, cols, &g, &b, 1e-5, &mut want);
        for &t in &THREADS {
            let be = SimdHost::with_threads(t);
            let mut got = vec![0.0f32; x.len()];
            be.layernorm_rows(&x, cols, &g, &b, 1e-5, &mut got);
            for (i, (a, w)) in got.iter().zip(want.iter()).enumerate() {
                assert!(
                    (a - w).abs() <= 2e-4 * (1.0 + w.abs()),
                    "layernorm rows={rows} cols={cols} t={t} i={i}: {a} vs {w}"
                );
            }
        }
    }
}

#[test]
fn layernorm_non_finite_rows_agree_on_nan_pattern() {
    // a row containing inf/NaN collapses to all-NaN on both backends
    // (the Welford second moment goes NaN); finite rows stay within the
    // cross-lane tolerance
    let oracle = ScalarHost;
    let mut rng = Rng::new(78);
    let (rows, cols) = (130usize, 65usize);
    let x = special_input(rng.normal_vec(rows * cols, 2.0));
    let g = rng.normal_vec(cols, 1.0);
    let b = rng.normal_vec(cols, 1.0);
    let mut want = vec![0.0f32; x.len()];
    oracle.layernorm_rows(&x, cols, &g, &b, 1e-5, &mut want);
    for &t in &THREADS {
        let be = SimdHost::with_threads(t);
        let mut got = vec![0.0f32; x.len()];
        be.layernorm_rows(&x, cols, &g, &b, 1e-5, &mut got);
        for (i, (a, w)) in got.iter().zip(want.iter()).enumerate() {
            assert_eq!(a.is_nan(), w.is_nan(), "layernorm t={t} i={i}: {a} vs {w}");
            if !w.is_nan() {
                assert!(
                    (a - w).abs() <= 2e-4 * (1.0 + w.abs()),
                    "layernorm t={t} i={i}: {a} vs {w}"
                );
            }
        }
    }
}

#[test]
fn adam_simd_matches_scalar_bit_for_bit() {
    let oracle = ScalarHost;
    let mut rng = Rng::new(4242);
    // 1 << 17 elements engage multi-worker banding at the 64k floor
    for &n in &[1usize, 7, 33, 64, 257, 1 << 17] {
        for variant in 0..2 {
            let p0 = rng.normal_vec(n, 1.0);
            let g = {
                let g = rng.normal_vec(n, 0.5);
                if variant == 0 {
                    g
                } else {
                    special_input(g)
                }
            };
            let m0 = rng.normal_vec(n, 0.1);
            let v0: Vec<f32> =
                rng.normal_vec(n, 0.1).iter().map(|x| x * x).collect();
            for step in [1usize, 7] {
                let (mut pw, mut mw, mut vw) =
                    (p0.clone(), m0.clone(), v0.clone());
                oracle.adam_step(step, 1e-3, &mut pw, &g, &mut mw, &mut vw);
                for &t in &THREADS {
                    let be = SimdHost::with_threads(t);
                    let (mut pg, mut mg, mut vg) =
                        (p0.clone(), m0.clone(), v0.clone());
                    be.adam_step(step, 1e-3, &mut pg, &g, &mut mg, &mut vg);
                    for (name, got, want) in
                        [("p", &pg, &pw), ("m", &mg, &mw), ("v", &vg, &vw)]
                    {
                        for (i, (a, b)) in got.iter().zip(want.iter()).enumerate()
                        {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "adam {name} n={n} step={step} t={t} \
                                 variant={variant} i={i}: {a} vs {b}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn elementwise_helpers_match_bit_for_bit() {
    let oracle = ScalarHost;
    let mut rng = Rng::new(5);
    for &n in &[1usize, 9, 63, 1 << 17] {
        let d0 = special_input(rng.normal_vec(n, 1.0));
        let s = rng.normal_vec(n, 1.0);
        let mut want = d0.clone();
        oracle.add_assign(&mut want, &s);
        oracle.scale(&mut want, 0.37);
        for &t in &THREADS {
            let be = SimdHost::with_threads(t);
            let mut got = d0.clone();
            be.add_assign(&mut got, &s);
            be.scale(&mut got, 0.37);
            for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "elementwise n={n} t={t} i={i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn thread_count_never_changes_simd_bits() {
    // banding splits whole rows (or pure elementwise ranges), so every
    // thread count must produce identical bits — including LayerNorm,
    // whose lane order differs from the oracle but never across bands
    let mut rng = Rng::new(31);
    let (rows, cols) = (520usize, 33usize);
    let x = special_input(rng.normal_vec(rows * cols, 2.0));
    let g = rng.normal_vec(cols, 1.0);
    let b = rng.normal_vec(cols, 1.0);
    let base = SimdHost::with_threads(1);
    let mut want_sm = vec![0.0f32; x.len()];
    base.softmax_rows(&x, cols, 0.125, &mut want_sm);
    let mut want_ln = vec![0.0f32; x.len()];
    base.layernorm_rows(&x, cols, &g, &b, 1e-5, &mut want_ln);
    for &t in &THREADS[1..] {
        let be = SimdHost::with_threads(t);
        let mut got = vec![0.0f32; x.len()];
        be.softmax_rows(&x, cols, 0.125, &mut got);
        assert!(
            got.iter().zip(&want_sm).all(|(a, b)| a.to_bits() == b.to_bits()),
            "softmax bits changed at t={t}"
        );
        let mut got = vec![0.0f32; x.len()];
        be.layernorm_rows(&x, cols, &g, &b, 1e-5, &mut got);
        assert!(
            got.iter().zip(&want_ln).all(|(a, b)| a.to_bits() == b.to_bits()),
            "layernorm bits changed at t={t}"
        );
    }
}
