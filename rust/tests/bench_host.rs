//! Tier-1 perf smoke: runs the host bench harness in quick mode, gates
//! the fused kernels against their naive chains and the view-based shard
//! moves against the copying reference, and emits the `BENCH_host.json`
//! ledger at the workspace root — so every `cargo test` run (local and
//! CI) leaves a fresh machine-readable perf record behind.
//!
//! Floors are deliberately loose on wall-clock-noisy metrics (fused must
//! simply not be *slower* than its multi-pass chain) and strict where
//! the win is structural (view shard moves are O(1) metadata vs an O(n)
//! gather — required ≥ 2×, in practice orders of magnitude).

use fastfold::bench::{run_host_bench, BenchOptions};

fn metric(doc: &fastfold::json::Json, section: &str, key: &str) -> f64 {
    doc.get(section)
        .and_then(|s| s.get(key))
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|e| panic!("missing {section}.{key}: {e}"))
}

#[test]
fn host_bench_quick_meets_floors_and_emits_ledger() {
    let doc = run_host_bench(BenchOptions { quick: true }).expect("bench runs");

    // structural win: O(1) views vs O(n) gather — far more than 2x in
    // any profile (the view path does no element work at all)
    let shard = metric(&doc, "shard_move", "speedup");
    assert!(shard >= 2.0, "view shard-move speedup {shard:.2}x < 2x");

    // kernel-ratio floors bind only in optimized builds: dev-profile
    // iterator overhead can invert fused-vs-naive without saying
    // anything about release behavior — the CI perf-smoke job gates the
    // release binary. The metrics must still exist and be finite here.
    for section in ["fused_softmax", "fused_layernorm", "fused_adam"] {
        let s = metric(&doc, section, "speedup");
        assert!(s.is_finite() && s > 0.0, "{section} speedup not measured: {s}");
        if cfg!(debug_assertions) {
            eprintln!("note: debug build — {section} floor ({s:.3}x) not enforced");
        } else {
            assert!(s > 1.0, "{section} fused slower than naive chain: {s:.3}x");
        }
    }

    // the rest of the ledger is present and sane
    assert!(metric(&doc, "ring_all_reduce", "gbps") > 0.0);
    assert!(metric(&doc, "ring_all_reduce", "wire_bytes") > 0.0);
    assert!(metric(&doc, "synthetic_train", "steps_per_sec") > 0.0);
    assert!(metric(&doc, "serve_makespan", "modeled_makespan_s") > 0.0);
    assert!(metric(&doc, "serve_makespan", "admitted") >= 1.0);

    // emit the ledger at the workspace root (best effort: a read-only
    // checkout must not fail the suite)
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_host.json");
    if let Err(e) = std::fs::write(path, format!("{doc}\n")) {
        eprintln!("note: could not write {path}: {e}");
    }
}
