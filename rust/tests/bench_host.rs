//! Tier-1 perf smoke: runs the host bench harness in quick mode, gates
//! the fused kernels against their naive chains and the view-based shard
//! moves against the copying reference, and emits a quick-mode ledger
//! under `target/` — so every `cargo test` run (local and CI) leaves a
//! fresh machine-readable perf record behind without dirtying the
//! checkout. The canonical `BENCH_host.json` at the repo root is written
//! only by an explicit `fastfold bench --json` (`--out` overrides).
//!
//! Floors are deliberately loose on wall-clock-noisy metrics (fused must
//! simply not be *slower* than its multi-pass chain) and strict where
//! the win is structural (view shard moves are O(1) metadata vs an O(n)
//! gather — required ≥ 2×, in practice orders of magnitude).

use fastfold::bench::{run_host_bench, BenchOptions};

fn metric(doc: &fastfold::json::Json, section: &str, key: &str) -> f64 {
    doc.get(section)
        .and_then(|s| s.get(key))
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|e| panic!("missing {section}.{key}: {e}"))
}

#[test]
fn host_bench_quick_meets_floors_and_emits_ledger() {
    let doc = run_host_bench(BenchOptions { quick: true }).expect("bench runs");

    // structural win: O(1) views vs O(n) gather — far more than 2x in
    // any profile (the view path does no element work at all)
    let shard = metric(&doc, "shard_move", "speedup");
    assert!(shard >= 2.0, "view shard-move speedup {shard:.2}x < 2x");

    // kernel-ratio floors bind only in optimized builds: dev-profile
    // iterator overhead can invert fused-vs-naive without saying
    // anything about release behavior — the CI perf-smoke job gates the
    // release binary. The metrics must still exist and be finite here.
    for section in ["fused_softmax", "fused_layernorm", "fused_adam"] {
        let s = metric(&doc, section, "speedup");
        assert!(s.is_finite() && s > 0.0, "{section} speedup not measured: {s}");
        if cfg!(debug_assertions) {
            eprintln!("note: debug build — {section} floor ({s:.3}x) not enforced");
        } else {
            assert!(s > 1.0, "{section} fused slower than naive chain: {s:.3}x");
        }
    }

    // v2 ledger: per-backend ratios and thread-scaling curves are
    // present and finite (their floors are CI-release-only — a debug
    // build or a 1-core box can legitimately measure ~1.0x)
    for section in ["fused_softmax", "fused_layernorm", "fused_adam"] {
        let r = metric(&doc, section, "simd_speedup");
        assert!(r.is_finite() && r > 0.0, "{section} simd_speedup not measured: {r}");
        assert!(metric(&doc, section, "scalar_us") > 0.0);
        assert!(metric(&doc, section, "simd_us") > 0.0);
    }
    for kernel in ["softmax", "layernorm"] {
        let ts = doc
            .get("thread_scaling")
            .and_then(|s| s.get(kernel))
            .unwrap_or_else(|e| panic!("missing thread_scaling.{kernel}: {e}"));
        for key in ["t1_us", "t2_us", "t4_us", "t8_us"] {
            let v = ts.get(key).and_then(|v| v.as_f64()).unwrap();
            assert!(v > 0.0, "thread_scaling.{kernel}.{key} = {v}");
        }
        let s4 = ts.get("scaling_1_to_4").and_then(|v| v.as_f64()).unwrap();
        assert!(s4.is_finite() && s4 > 0.0);
    }

    // the rest of the ledger is present and sane
    assert!(metric(&doc, "ring_all_reduce", "gbps") > 0.0);
    assert!(metric(&doc, "ring_all_reduce", "wire_bytes") > 0.0);
    assert!(metric(&doc, "synthetic_train", "steps_per_sec") > 0.0);
    assert!(metric(&doc, "serve_makespan", "modeled_makespan_s") > 0.0);
    assert!(metric(&doc, "serve_makespan", "admitted") >= 1.0);

    // emit the quick ledger under target/ (best effort: a read-only
    // checkout must not fail the suite); the repo root stays clean —
    // only `fastfold bench --json` writes BENCH_host.json there
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/target/BENCH_host.quick.json"
    );
    if let Err(e) = std::fs::write(path, format!("{doc}\n")) {
        eprintln!("note: could not write {path}: {e}");
    }
}
