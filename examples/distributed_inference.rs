//! Distributed (DAP) inference vs single-device — the paper's §V.C
//! long-sequence scenario at executable scale: run the same model under
//! DAP degrees 1/2/4, verify numerics against single-device, and report
//! wall time, per-rank simulated time (the 1-core stand-in for N devices),
//! and the Duality-Async overlap ablation.
//!
//! ```sh
//! cargo run --release --example distributed_inference -- [preset]
//! ```

use fastfold::dap::DapCoordinator;
use fastfold::metrics::{fmt_secs, Table};
use fastfold::runtime::Runtime;
use fastfold::train::DataGen;

fn main() -> fastfold::Result<()> {
    let preset = std::env::args().nth(1).unwrap_or_else(|| "small".into());
    let rt = Runtime::new("artifacts")?;
    let params = rt.manifest.load_params(&preset)?;
    let cfg = fastfold::config::ModelConfig::preset(&preset)?;
    let mut gen = DataGen::new(cfg.clone(), 31);
    let batch = gen.next_batch();

    println!("[distributed_inference] preset '{preset}' (N_res={}, N_seq={}, {} blocks)",
             cfg.n_res, cfg.n_seq, cfg.n_blocks);

    // reference single-device
    let t0 = std::time::Instant::now();
    let (m_ref, z_ref) = fastfold::inference::single_device_forward(
        &rt, &preset, &params, &batch.msa_tokens, false)?;
    let t_single = t0.elapsed().as_secs_f64();
    println!("single device: {}", fmt_secs(t_single));

    let mut table = Table::new(&[
        "DAP", "wall (1 core)", "sim step (overlap)", "sim step (sync)",
        "exposed comm", "max|Δ| vs single",
    ]);
    for n in [1usize, 2, 4] {
        if cfg.n_seq % n != 0 || cfg.n_res % n != 0 {
            continue;
        }
        let run = |overlap: bool| -> fastfold::Result<(f64, f64, f64, f64)> {
            let co = DapCoordinator::new(&rt, &preset, n, overlap)?;
            let t0 = std::time::Instant::now();
            let (m_d, z_d) = co.model_forward(&params, &batch.msa_tokens)?;
            let wall = t0.elapsed().as_secs_f64();
            let tl = co.timeline.lock().unwrap();
            let diff = m_d.max_abs_diff(&m_ref).max(z_d.max_abs_diff(&z_ref));
            Ok((wall, tl.elapsed(), tl.exposed_comm_seconds, diff as f64))
        };
        let (wall, sim_on, exposed, diff) = run(true)?;
        let (_, sim_off, _, _) = run(false)?;
        table.row(&[
            n.to_string(),
            fmt_secs(wall),
            fmt_secs(sim_on),
            fmt_secs(sim_off),
            fmt_secs(exposed),
            format!("{diff:.2e}"),
        ]);
    }
    table.print();
    println!("\n(sim step = dual-stream timeline: per-rank compute ‖ comm stream —");
    println!(" the Duality-Async model of paper Fig 7; wall = all ranks serialized");
    println!(" on this 1-core testbed.)");
    Ok(())
}
