//! Batch serving through the unified inference engine — the ParaFold-style
//! scenario: one process, many heterogeneous requests, a backend chosen
//! per request by the cost model.
//!
//! ```sh
//! cargo run --release --example batch_serve            # plan-only (no artifacts)
//! cargo run --release --example batch_serve -- exec    # executed drain (needs artifacts)
//! ```
//!
//! Without artifacts this prints the placement/schedule preview (what
//! `fastfold serve --dry-run` shows); with artifacts it drains an
//! executable tiny/small batch through the real backends and reports
//! per-request wall latency next to the modeled figures.

use fastfold::config::RunConfig;
use fastfold::inference::engine::{
    plan_batch, BackendKind, Engine, InferRequest, PlacementPlanner, SchedPolicy,
};
use fastfold::metrics::fmt_secs;
use fastfold::runtime::Runtime;

fn paper_scale_batch() -> Vec<InferRequest> {
    [256usize, 1024, 2048, 2560, 3072, 4096]
        .iter()
        .enumerate()
        .map(|(k, &len)| {
            let mut r = InferRequest::new(&format!("seq-{len}"), "tiny");
            r.model_len = Some(len);
            r.seed = 40 + k as u64;
            r
        })
        .collect()
}

fn main() -> fastfold::Result<()> {
    let exec = std::env::args().nth(1).as_deref() == Some("exec");
    let run_cfg = RunConfig {
        serve: fastfold::config::ServeConfig {
            policy: SchedPolicy::Sjf,
            ..Default::default()
        },
        ..Default::default()
    };

    if !exec {
        // plan-only: placement decision tree + schedule at paper scale,
        // through the same plan_batch pipeline Engine::serve runs
        let planner = PlacementPlanner::from_run_config(&run_cfg)?;
        let requests = paper_scale_batch();
        println!(
            "[batch_serve] planning {} requests on {} (policy=sjf)\n",
            requests.len(),
            planner.gpu.name
        );
        let plan = plan_batch(
            &planner,
            SchedPolicy::Sjf,
            run_cfg.serve.max_bypass,
            4,
            &requests,
        );
        plan.table(&requests).print();
        for line in plan.rejections(&requests) {
            println!("  {line}");
        }
        println!(
            "\nSJF schedule over 4 lanes: modeled makespan {}",
            fmt_secs(plan.modeled_makespan)
        );
        println!("(run with `-- exec` and artifacts for the executed drain)");
        return Ok(());
    }

    // executed drain: tiny-preset requests, one forced DAP job in the mix
    let rt = Runtime::new("artifacts")?;
    let engine = Engine::new(&rt, &run_cfg)?;
    let mut dap = InferRequest::new("dap2", "tiny");
    dap.force = Some(BackendKind::Dap(2));
    let mut long = InferRequest::new("long-2048", "tiny");
    long.model_len = Some(2048);
    let requests = vec![
        InferRequest::new("a", "tiny"),
        dap,
        long,
        InferRequest::new("b", "tiny"),
    ];
    println!("[batch_serve] draining {} executable requests\n", requests.len());
    let report = engine.serve(&requests)?;
    report.table().print();
    println!("\n[batch_serve] {}", report.summary());
    Ok(())
}
