//! Quickstart: load the AOT artifacts, run one Evoformer block and a full
//! mini-AlphaFold forward on synthetic data, single device.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use fastfold::config::ModelConfig;
use fastfold::inference::single_device_forward;
use fastfold::metrics::fmt_secs;
use fastfold::runtime::Runtime;
use fastfold::train::DataGen;

fn main() -> fastfold::Result<()> {
    let rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    let preset = "tiny";
    let cfg = ModelConfig::preset(preset)?;
    println!(
        "preset '{preset}': N_res={} N_seq={} d_msa={} d_pair={} blocks={} ({} params)",
        cfg.n_res,
        cfg.n_seq,
        cfg.d_msa,
        cfg.d_pair,
        cfg.n_blocks,
        cfg.param_count()
    );

    // load parameters exported by the python compile path
    let params = rt.manifest.load_params(preset)?;

    // synthetic co-evolution batch (DESIGN.md §2 data substitution)
    let mut gen = DataGen::new(cfg.clone(), 42);
    let batch = gen.next_batch();

    // one Evoformer block, standalone
    let block = rt.load(&format!("{preset}/block_fwd"))?;
    let idx = rt.manifest.block_leaf_indices(preset, 0)?;
    let mut args: Vec<_> = idx.iter().map(|&i| params[i].clone()).collect();
    args.push(fastfold::HostTensor::zeros(&[cfg.n_seq, cfg.n_res, cfg.d_msa]));
    args.push(fastfold::HostTensor::zeros(&[cfg.n_res, cfg.n_res, cfg.d_pair]));
    let t0 = std::time::Instant::now();
    let out = block.run_f32(&args)?;
    println!(
        "block_fwd: m{:?} z{:?} in {}",
        out[0].shape,
        out[1].shape,
        fmt_secs(t0.elapsed().as_secs_f64())
    );

    // full model: embed -> blocks -> heads
    let t0 = std::time::Instant::now();
    let (msa_logits, dist_logits) =
        single_device_forward(&rt, preset, &params, &batch.msa_tokens, false)?;
    println!(
        "model forward: msa_logits{:?} dist_logits{:?} in {}",
        msa_logits.shape,
        dist_logits.shape,
        fmt_secs(t0.elapsed().as_secs_f64())
    );
    println!(
        "compiled {} executables in {:.2}s total",
        rt.cached(),
        rt.compile_seconds.lock().unwrap()
    );
    Ok(())
}
