//! Full scaling report: regenerates every model-driven paper result in one
//! run (Table II, Fig 10, Fig 11, Table IV, Fig 13, Table V) from the
//! calibrated analytic models. Pure computation — no artifacts needed.
//!
//! ```sh
//! cargo run --release --example scaling_report
//! ```

use fastfold::config::ModelConfig;
use fastfold::inference::chunking;
use fastfold::metrics::Table;
use fastfold::perfmodel::gpu::ImplProfile;
use fastfold::perfmodel::scaling::{MpMethod, ScalingModel};
use fastfold::perfmodel::{GpuSpec, MemoryModel};

fn main() {
    let m = ScalingModel::default();
    let ff = ImplProfile::fastfold();
    let of = ImplProfile::openfold();

    println!("==================== Fig 10: model-parallel scaling ====================");
    for (label, cfg) in [
        ("Initial Training", ModelConfig::initial_training()),
        ("Fine-tuning", ModelConfig::finetune()),
    ] {
        println!("\n{label}:");
        let mut t = Table::new(&["GPUs", "DAP eff", "TP eff", "DAP w/o overlap"]);
        let t1 = m.train_step(&cfg, &ff, MpMethod::Dap, 1, true).total();
        for n in [1usize, 2, 4] {
            let dap = m.train_step(&cfg, &ff, MpMethod::Dap, n, true).total();
            let dap_sync = m.train_step(&cfg, &ff, MpMethod::Dap, n, false).total();
            let tp = m.train_step(&cfg, &ff, MpMethod::TensorParallel, n, true).total();
            t.row(&[
                n.to_string(),
                format!("{:.1}%", 100.0 * t1 / (n as f64 * dap)),
                format!("{:.1}%", 100.0 * t1 / (n as f64 * tp)),
                format!("{:.1}%", 100.0 * t1 / (n as f64 * dap_sync)),
            ]);
        }
        t.print();
    }

    println!("\n==================== Fig 11: data-parallel scaling =====================");
    let cfg = ModelConfig::finetune();
    let mp = m.train_step(&cfg, &ff, MpMethod::Dap, 4, true).total();
    let mut t = Table::new(&["nodes", "efficiency"]);
    for n in [1usize, 4, 16, 64, 128] {
        let step = m.dp_step(&cfg, mp, n);
        t.row(&[n.to_string(), format!("{:.1}%", 100.0 * mp / step)]);
    }
    t.print();
    println!("(paper: 90.1% at 128 nodes)");

    println!("\n==================== Table IV: training cost ===========================");
    let init = ModelConfig::initial_training();
    let step_of = m.dp_step(&init, m.train_step(&init, &of, MpMethod::Dap, 1, true).total(), 128);
    let step_ff = m.dp_step(&init, m.train_step(&init, &ff, MpMethod::Dap, 2, true).total(), 128);
    let ft = ModelConfig::finetune();
    let ft_of = m.dp_step(&ft, m.train_step(&ft, &of, MpMethod::Dap, 1, true).total(), 128);
    let ft_ff = m.dp_step(&ft, m.train_step(&ft, &ff, MpMethod::Dap, 4, true).total(), 128);
    let days = |si: f64, sf: f64| (si * 78125.0 + sf * 11719.0) / 86400.0;
    println!("OpenFold : init {step_of:.2}s  ft {ft_of:.2}s  total {:.2} days (paper 8.39)", days(step_of, ft_of));
    println!("FastFold : init {step_ff:.2}s  ft {ft_ff:.2}s  total {:.2} days (paper 2.81)", days(step_ff, ft_ff));
    println!("speedup  : {:.2}x (paper 2.98x vs OpenFold)", days(step_of, ft_of) / days(step_ff, ft_ff));

    println!("\n==================== Fig 13 / Table V: long sequences ==================");
    let mem = MemoryModel::default();
    let gpu = GpuSpec::a100_40g();
    let mut t = Table::new(&["len", "OpenFold", "FastFold 8 GPU", "speedup", "FF4 verdict"]);
    for &len in &[1024usize, 2048, 2560, 3072, 3584, 4096] {
        let of_cell = match chunking::plan_chunks(&ModelConfig::inference(len), &mem, &gpu) {
            Some(p) => format!(
                "{:.0} s",
                m.inference_latency(len, &of, MpMethod::Dap, 1, p.chunks > 1)
            ),
            None => "OOM".into(),
        };
        let ff8 = m.inference_latency(len, &ff, MpMethod::Dap, 8, false);
        let speedup = match chunking::plan_chunks(&ModelConfig::inference(len), &mem, &gpu) {
            Some(p) => format!(
                "{:.1}x",
                m.inference_latency(len, &of, MpMethod::Dap, 1, p.chunks > 1) / ff8
            ),
            None => "∞ (OOM)".into(),
        };
        let ff4 = match mem.check(&ModelConfig::inference(len), 4, 1, gpu.memory) {
            Ok(_) => format!("{:.0} s", m.inference_latency(len, &ff, MpMethod::Dap, 4, false)),
            Err(_) => "OOM".into(),
        };
        t.row(&[len.to_string(), of_cell, format!("{ff8:.0} s"), speedup, ff4]);
    }
    t.print();
    println!("(paper Fig 13: 7.5–9.5x vs OpenFold; Table V: OOM at 3072 single-GPU,");
    println!(" FastFold-4 OOM only at 4096.)");
}
