//! End-to-end training driver (DESIGN.md §7): train the mini-AlphaFold on
//! synthetic co-evolution data under a hybrid DP×DAP plan and log the
//! loss curve. This is the run recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example train_e2e -- [preset] [steps] [dp] [dap] [accum]
//! # defaults: small 300 2 1 1
//! ```
//!
//! Writes the loss curve to train_e2e_loss.csv.

use fastfold::config::TrainConfig;
use fastfold::metrics::{fmt_bytes, fmt_secs};
use fastfold::perfmodel::flops::train_step_flops;
use fastfold::runtime::Runtime;
use fastfold::train::{ParallelPlan, Trainer};
use std::io::Write;

fn main() -> fastfold::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(|s| s.as_str()).unwrap_or("small").to_string();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let dp: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let dap: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(1);
    let accum: usize = args.get(4).and_then(|s| s.parse().ok()).unwrap_or(1);

    let rt = Runtime::new("artifacts")?;
    let plan = ParallelPlan::new(dp, dap, accum).with_threads(0);
    println!(
        "[train_e2e] preset='{preset}' steps={steps} [{plan}] platform={}",
        rt.platform()
    );
    let cfg = TrainConfig {
        steps,
        lr: 1e-3,
        warmup_steps: 20,
        log_every: 10,
        checkpoint_every: 100,
        checkpoint_dir: Some("checkpoints".into()),
        seed: 42,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::hybrid(&rt, &preset, plan, true, cfg)?;
    let report = trainer.run()?;

    // loss curve
    let mut f = std::fs::File::create("train_e2e_loss.csv")?;
    writeln!(f, "step,loss")?;
    for (s, l) in &trainer.history {
        writeln!(f, "{s},{l}")?;
    }

    let model_cfg = fastfold::config::ModelConfig::preset(&preset)?;
    let flops = train_step_flops(&model_cfg, 1.0) * plan.effective_batch() as f64;
    println!("\n[train_e2e] summary");
    println!("  loss: {:.4} -> {:.4} over {} steps", report.initial_loss,
             report.final_loss, report.steps);
    println!("  wall: {} ({:.3} steps/s, {:.1} MFLOP/s effective)",
             fmt_secs(report.seconds), report.steps_per_sec,
             report.steps_per_sec * flops / 1e6);
    println!("  wire: DP ring {} / DAP collectives {}",
             fmt_bytes(report.wire_bytes), fmt_bytes(report.wire_dap_bytes));
    println!("  loss curve -> train_e2e_loss.csv; checkpoints -> checkpoints/");
    if report.final_loss >= report.initial_loss {
        eprintln!("WARNING: loss did not decrease");
        std::process::exit(1);
    }
    Ok(())
}
